"""Analytic per-cell cost model (FLOPs / HBM bytes / collective bytes).

Why this exists: XLA's ``HloCostAnalysis`` counts each while-loop body
ONCE, and every big loop in this framework is deliberately rolled
(stacked-layer scan, microbatch scan, flash q/kv block scans, rwkv chunk
scan) to keep 512-device compiles tractable — so module-level
``compiled.cost_analysis()`` under-reports by the product of trip counts
(verified: qwen3-8b train_4k reports ~1e13 FLOPs/device where the
arithmetic is ~8e14).  The roofline terms are therefore derived here
from the architecture directly — every formula is plain napkin math over
the published config — and the HLO numbers are kept in the table as the
loop-body-once cross-check.

Conventions (global quantities; divide by chips at the end):

* matmul forward FLOPs = 2 · N_mm · tokens, N_mm = active params minus
  the input-embedding table (a gather, not a matmul; tied embeddings
  still pay the head matmul).
* attention forward FLOPs = 4 · B · S · S_ctx · (Hq·dh) per attn layer —
  the flash kernel computes every (q, kv) block and masks, so causal /
  windowed cells pay full S·S_ctx on the MXU (counted as compiled; the
  useful-vs-compiled gap is reported, and block-skipping is a §Perf
  lever).
* train total = 4 × forward (backward 2×, remat recompute 1×; the flash
  backward's probability recompute is folded into this factor).
* HBM bytes: optimizer state r/w (16 N f32), weight-shard reads per use
  (fwd+bwd+remat per microbatch), activation traffic per layer
  (~6 accesses of (B, S, d) bf16 per pass), KV/state cache traffic.
* collectives (2-D fully-sharded weights on (data, model)):
    - weight all-gather over the data axes: (2·N / model) per use,
      3 uses (fwd, bwd, remat) per microbatch;
    - gradient reduction over data: reduce-scatter + all-gather of f32
      grads ≈ 8·N / model;
    - tensor-parallel activation all-reduces: 2 per attn/mlp pair per
      layer per pass, each moving ~2 × tensor bytes / chips per chip;
    - MoE: dispatch/combine all-to-all over the expert axis,
      2 · tokens · d · bf16 / chips each way.
"""
from __future__ import annotations

import dataclasses

from repro.configs import SHAPES, ShapeSpec
from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


@dataclasses.dataclass
class CellCost:
    flops: float            # global FLOPs per step
    hbm_bytes: float        # per-chip HBM traffic per step
    coll_bytes: float       # per-chip collective traffic per step
    flops_useful: float     # MODEL_FLOPS (6·N·D / 2·N·D)
    breakdown: dict

    def terms(self, chips: int) -> dict:
        t_c = self.flops / chips / PEAK_FLOPS
        t_m = self.hbm_bytes / HBM_BW
        t_x = self.coll_bytes / ICI_BW
        dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
        t_star = max(t_c, t_m, t_x)
        return dict(
            t_compute=t_c, t_memory=t_m, t_collective=t_x, dominant=dom,
            useful_ratio=self.flops_useful / self.flops,
            roofline_fraction=(self.flops_useful / chips / PEAK_FLOPS)
            / t_star if t_star else 0.0)


def _microbatches(cfg: ModelConfig) -> int:
    n = cfg.param_count()
    return 8 if n > 50e9 else (4 if n > 10e9 else 2)


def _n_attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for i in range(cfg.n_layers) if cfg.mixer_of(i) == "attn")


def _n_rec_layers(cfg: ModelConfig) -> int:
    return cfg.n_layers - _n_attn_layers(cfg)


def _n_mm(cfg: ModelConfig) -> float:
    n = cfg.param_count(active_only=True)
    if not cfg.tie_embeddings:
        n -= cfg.vocab_size * cfg.d_model      # input table is a gather
    if cfg.family == "encdec":
        pass                                    # head counted in params
    return float(n)


def _attn_fwd_flops(cfg: ModelConfig, B: int, Sq: int, Sctx: int) -> float:
    return 4.0 * B * Sq * Sctx * cfg.n_heads * cfg.head_dim


def _rec_fwd_flops(cfg: ModelConfig, B: int, S: int) -> float:
    if cfg.attn_free or cfg.family == "hybrid":
        per_tok = (5 * cfg.d_model * cfg.rwkv_head_dim
                   if "rwkv6" in cfg.mixer_pattern
                   else 10 * (cfg.rglru_d_rnn or cfg.d_model))
        return float(_n_rec_layers(cfg) * B * S * per_tok)
    return 0.0


def _fwd_flops(cfg: ModelConfig, B: int, S: int) -> float:
    mm = 2.0 * _n_mm(cfg) * B * S
    attn = _n_attn_layers(cfg) * _attn_fwd_flops(cfg, B, S, S)
    if cfg.family == "encdec":
        F = cfg.encoder_seq
        attn += cfg.n_encoder_layers * _attn_fwd_flops(cfg, B, F, F)
        attn += cfg.n_layers * _attn_fwd_flops(cfg, B, S, F)  # cross
    return mm + attn + _rec_fwd_flops(cfg, B, S)


def _act_bytes(cfg: ModelConfig, B: int, S: int, passes: float) -> float:
    """~6 (B,S,d)-bf16 accesses per layer per pass."""
    return 6.0 * cfg.n_layers * B * S * cfg.d_model * 2 * passes


def _cache_bytes(cfg: ModelConfig, B: int, S_ctx: int) -> float:
    per_attn = 2 * B * min(S_ctx, cfg.sliding_window or S_ctx) * \
        cfg.n_kv_heads * cfg.head_dim * 2
    rec = _n_rec_layers(cfg) * B * (
        (cfg.d_model // cfg.rwkv_head_dim) * cfg.rwkv_head_dim ** 2 * 4
        if "rwkv6" in cfg.mixer_pattern else
        (cfg.rglru_d_rnn or cfg.d_model) * 4)
    return _n_attn_layers(cfg) * per_attn + rec


def analytic_cell(arch_cfg: ModelConfig, shape: ShapeSpec,
                  chips: int, model_axis: int = 16) -> CellCost:
    cfg, B, S = arch_cfg, shape.global_batch, shape.seq_len
    N = cfg.param_count()
    N_act = cfg.param_count(active_only=True)
    bd = {}

    if shape.kind == "train":
        mb = _microbatches(cfg)
        tokens = B * S
        fwd = _fwd_flops(cfg, B, S)
        flops = 4.0 * fwd                                  # fwd+remat+2·bwd
        useful = 6.0 * N_act * tokens
        hbm = (16.0 * N                                    # m, v r/w (f32)
               + 4.0 * N                                   # params r/w bf16
               + 3.0 * mb * 2.0 * N                        # shard reads/use
               ) / chips + _act_bytes(cfg, B, S, 3.0) / chips
        # collectives.  Weight all-gathers move FULL params (under EP
        # every local expert's data-shard is gathered, active or not).
        wt_ag = 3.0 * mb * 2.0 * N / model_axis            # weight AG/use
        grad = 8.0 * N / model_axis                        # RS+AG f32
        tp_ar = (2.0 * cfg.n_layers * 3.0 * mb
                 * 2.0 * (B // mb) * S * cfg.d_model * 2 / chips)
        moe_a2a = 0.0
        if cfg.moe is not None:
            moe_a2a = (2.0 * cfg.n_layers * 3.0
                       * 2.0 * tokens * cfg.d_model * 2 / chips)
        coll = wt_ag + grad + tp_ar + moe_a2a
        bd = dict(weight_ag=wt_ag, grad_sync=grad, tp_allreduce=tp_ar,
                  moe_a2a=moe_a2a)
        return CellCost(flops, hbm, coll, useful, bd)

    if shape.kind == "prefill":
        tokens = B * S
        flops = _fwd_flops(cfg, B, S)
        useful = 2.0 * N_act * tokens
        hbm = 2.0 * N / chips + _act_bytes(cfg, B, S, 1.0) / chips \
            + _cache_bytes(cfg, B, S) / chips
        wt_ag = 2.0 * N / model_axis
        tp_ar = (2.0 * cfg.n_layers * 2.0 * B * S * cfg.d_model * 2
                 / chips)
        moe_a2a = (2.0 * cfg.n_layers * 2.0 * tokens * cfg.d_model * 2
                   / chips if cfg.moe is not None else 0.0)
        coll = wt_ag + tp_ar + moe_a2a
        return CellCost(flops, hbm, coll, useful,
                        dict(weight_ag=wt_ag, tp_allreduce=tp_ar,
                             moe_a2a=moe_a2a))

    # decode: one token per sequence against an S-token cache
    S_ctx = S
    mm = 2.0 * _n_mm(cfg) * B
    attn = _n_attn_layers(cfg) * _attn_fwd_flops(
        cfg, B, 1, min(S_ctx, cfg.sliding_window or S_ctx))
    if cfg.family == "encdec":
        attn += cfg.n_layers * _attn_fwd_flops(cfg, B, 1, cfg.encoder_seq)
    rec = _rec_fwd_flops(cfg, B, 1)
    flops = mm + attn + rec
    useful = 2.0 * N_act * B
    hbm = (2.0 * N + 2.0 * _cache_bytes(cfg, B, S_ctx)) / chips \
        + _act_bytes(cfg, B, 1, 1.0) / chips
    wt_ag = 2.0 * N / model_axis
    tp_ar = 2.0 * cfg.n_layers * 2.0 * B * cfg.d_model * 2 / chips
    moe_a2a = (2.0 * cfg.n_layers * 2.0 * B * cfg.d_model * 2 / chips
               if cfg.moe is not None else 0.0)
    coll = wt_ag + tp_ar + moe_a2a
    return CellCost(flops, hbm, coll, useful,
                    dict(weight_ag=wt_ag, tp_allreduce=tp_ar,
                         moe_a2a=moe_a2a))
