"""Fig 9 reproduction: sensitivity to a more aggressive machine.

The paper re-simulates on a hypothetical core 2× wider and 3.5× deeper
with VLDP+IMP-class prefetchers, observing (a) short lookaheads lose
their benefit (the bigger instruction window already covers them) and
(b) speedups stabilise once the prefetch distance clears the window.

The TPU translation: the "instruction window" is the depth of the
hardware-managed Pallas double-buffer pipeline (effectively covering
k≈1–2), and a more aggressive memory system = higher HBM bandwidth /
lower latency.  We re-evaluate the roofline model of fig7 under a
hypothetical chip with 2× HBM bandwidth and 0.5× latency and report the
modelled speedup per prefetch distance.
"""
from __future__ import annotations

import dataclasses

from repro.core import planner

from .fig7_sweep import DISTANCES
from .harness import csv_row

V5E_AGGR = dataclasses.replace(planner.V5E, hbm_bw=planner.V5E.hbm_bw * 2,
                               hbm_latency=planner.V5E.hbm_latency * 0.5)

WINDOW_COVER = 2   # lookahead depth the hardware pipeline already covers


def model_speedup(hw, k, iter_flops=200.0, iter_bytes=64.0,
                  row_bytes=256.0) -> float:
    t_iter = planner.iter_time(iter_flops, iter_bytes + row_bytes, hw)
    k_eff = max(k, WINDOW_COVER)        # window already covers small k
    t_base = t_iter + hw.hbm_latency / WINDOW_COVER
    t_pf = max(t_iter, hw.hbm_latency / k_eff)
    return t_base / max(t_pf, 1e-12)


def run() -> list[str]:
    rows = []
    for hw, tag in ((planner.V5E, "v5e"), (V5E_AGGR, "aggressive")):
        for k in DISTANCES:
            s = model_speedup(hw, k)
            rows.append(csv_row(f"fig9.{tag}.k{k}", 0.0,
                                f"modelled_speedup={s:.2f}"))
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
