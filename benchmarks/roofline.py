"""Roofline analysis per (arch × shape × mesh) — deliverable (g).

Three terms per cell:

    compute    = FLOPs / (chips × 197e12)
    memory     = HBM bytes / (chips × 819e9)
    collective = collective bytes / (chips × 50e9)

Sources — two per cell, both reported:

* **analytic** (primary, used for the terms): derived from the
  architecture in :mod:`benchmarks.analytic`.  Necessary because XLA's
  ``HloCostAnalysis`` counts while-loop bodies ONCE and every heavy loop
  here is rolled (stacked-layer scan, microbatch scan, flash block
  scans) — the module-level numbers under-report by the trip-count
  product;
* **hlo** (cross-check): ``compiled.cost_analysis()`` FLOPs/bytes and
  the collective-op bytes parsed from the partitioned
  ``compiled.as_text()`` — i.e. per-device, loop-bodies-once.  Useful
  relatively (same loop structure between perf-iteration variants) and
  as the proof that the lower+compile deliverable ran.

MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N_active for MoE; the
useful_ratio = MODEL_FLOPS / compiled FLOPs exposes remat/recompute and
masked-attention waste.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHS, SHAPES

from .analytic import ICI_BW, PEAK_FLOPS, HBM_BW, analytic_cell

MESH_MODEL_AXIS = 16


def model_flops(arch: str, shape_name: str) -> float:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch

def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = rec["n_devices"]
    cfg = ARCHS[rec["arch"]]
    shape = SHAPES[rec["shape"]]
    cost = analytic_cell(cfg, shape, chips, MESH_MODEL_AXIS)
    out = cost.terms(chips)
    out.update(
        rec=rec, model_flops=cost.flops_useful,
        flops_analytic=cost.flops, hbm_analytic=cost.hbm_bytes,
        coll_analytic=cost.coll_bytes,
        hlo_flops_dev=rec.get("flops", -1.0),
        hlo_bytes_dev=rec.get("bytes_accessed", -1.0),
        hlo_coll_dev=rec.get("collectives", {}).get("total", -1),
        breakdown=cost.breakdown)
    return out


def load_all(dirpath: str = "benchmarks/results/dryrun") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def table(dirpath: str = "benchmarks/results/dryrun",
          mesh: str = "single") -> list[str]:
    rows = ["arch,shape,mesh,status,t_compute_s,t_memory_s,"
            "t_collective_s,dominant,model_flops,useful_ratio,"
            "roofline_fraction,hlo_flops_dev,hlo_coll_dev"]
    for rec in load_all(dirpath):
        if rec.get("mesh") != mesh or rec.get("opts"):
            continue        # perf-variant records live in §Perf, not here
        tag = f"{rec['arch']},{rec['shape']},{rec['mesh']}"
        if rec.get("status") == "skipped":
            rows.append(f"{tag},skipped,,,,,,,,,")
            continue
        a = analyze(rec)
        if a is None:
            rows.append(f"{tag},error,,,,,,,,,")
            continue
        rows.append(
            f"{tag},ok,{a['t_compute']:.4e},{a['t_memory']:.4e},"
            f"{a['t_collective']:.4e},{a['dominant']},"
            f"{a['model_flops']:.3e},{a['useful_ratio']:.3f},"
            f"{a['roofline_fraction']:.3f},{a['hlo_flops_dev']:.3e},"
            f"{a['hlo_coll_dev']:.3e}")
    return rows


def main():
    for mesh in ("single", "multi"):
        print(f"# roofline table ({mesh}-pod)")
        for r in table(mesh=mesh):
            print(r)


if __name__ == "__main__":
    main()
