"""Table 2 reproduction: control/dataflow analysis results per workload.

Runs the DIL screen (repro.core.dil) over each workload's hot loop and
reports loads / DILs / prefetchable DILs — the analogue of the paper's
pintool+simulator pipeline, on jaxpr dataflow.
"""
from __future__ import annotations

from repro.core import dil

from . import workloads as W


def run(input_id: int = 1) -> list[str]:
    rows = ["workload,loads,DILs,prefetchable,critical"]
    for name in W.WORKLOADS:
        wl = W.build(name, input_id)
        rep = dil.screen_loop(wl.loop_body, wl.loop_init,
                              jax.tree.map(lambda a: a[0], wl.loop_xs)
                              if wl.loop_xs is not None else None,
                              delinquent_bytes=1 << 16)
        rows.append(f"{name},{len(rep.loads)},{len(rep.dils)},"
                    f"{len(rep.prefetchable)},{len(rep.critical_targets)}")
    return rows


import jax  # noqa: E402  (used in run())


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
