"""Timing harness: the paper's methodology (5 runs, report the median)."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *, runs: int = 5, warmup: int = 2) -> float:
    """Median wall-clock seconds per call of a jitted nullary fn."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(runs):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
