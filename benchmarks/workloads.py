"""The paper's five irregular-memory workloads, in JAX (§5.1).

The unit of reproduction is the *memory access pattern*: hash tables are
open-addressing int32 arrays, graphs are CSR with padded neighbor lists.
Each workload exposes four implementations:

* ``baseline``  — the unmodified loop (lax.scan), the paper's pre-
                  optimization binary;
* ``pipelined`` — the automatic carrot-and-horse rewrite
                  (:func:`repro.core.prefetch_scan`) at distance ``k``;
* ``kernel``    — the Pallas inline-prefetch kernel path (vectorised,
                  interpret-mode on CPU);
* ``helper``    — a decoupled two-pass "helper thread" analogue: an
                  address pass + a gather pass in a separate dispatch,
                  with the paper's measured 3–30 µs spawn cost modelled
                  (Fig 4 / Fig 10 comparisons).

Mutation note (STLHistogram): the paper's `prefetcht0` is *non-binding*,
so prefetching a bucket that a nearby iteration increments is harmless.
Our TPU prefetch is *binding* (values are forwarded), so the histogram is
decomposed — probe the immutable key table with the inline prefetcher
(the delinquent chain), then scatter-add the resolved slots — the
canonical TPU formulation of a read-modify-write hash loop.  The DIL
screen itself enforces this: it only certifies loads from loop-invariant
tables.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline
from repro.kernels import (csr_gather_mean, hash_probe, build_table,
                           prefetch_gather)
from repro.kernels.hash_probe.ref import HASH_MULT, bucket_of

WINDOW = 8
LINE = 8


# ---------------------------------------------------------------------------
# input scales (paper Table 1 / Table 3, scaled to CPU-tractable sizes
# with the same does-not-fit-in-cache character)
# ---------------------------------------------------------------------------

INPUTS = {
    1: dict(histo_n=65536, histo_unique=16384, slots=1 << 17,
            graph_nodes=16384, graph_deg=6, join_build=16384,
            join_probe=65536, cuckoo_flows=16384),
    2: dict(histo_n=131072, histo_unique=16384, slots=1 << 17,
            graph_nodes=32768, graph_deg=4, join_build=32768,
            join_probe=131072, cuckoo_flows=32768),
}

# Per-iteration cost profiles for the v5e roofline models (fig4/7/9/10):
#   iter_flops / iter_bytes — the horse's own work per iteration,
#   dil_bytes               — bytes moved by the DIL gather(s),
#   alloc_epoch             — iterations per helper respawn (paper §3.1:
#                             Cuckoo's 32-wide bulk loop respawns per
#                             bulk -> the paper's fig10 outlier),
#   inner_trip              — inner-loop trip count capping useful
#                             lookahead (PageRank avg degree, §5.2.2).
PROFILES = {
    "STLHistogram": dict(iter_flops=50, iter_bytes=8, dil_bytes=256,
                         alloc_epoch=256, inner_trip=None),
    "PageRank": dict(iter_flops=30, iter_bytes=8, dil_bytes=48,
                     alloc_epoch=4096, inner_trip=6),
    "HashJoin": dict(iter_flops=50, iter_bytes=8, dil_bytes=256,
                     alloc_epoch=4096, inner_trip=None),
    "Graph500CSR": dict(iter_flops=20, iter_bytes=8, dil_bytes=48,
                        alloc_epoch=4096, inner_trip=None),
    "Cuckoo": dict(iter_flops=80, iter_bytes=8, dil_bytes=512,
                   alloc_epoch=32, inner_trip=32),
}


@dataclasses.dataclass
class Workload:
    name: str
    data: dict
    baseline: callable          # () -> result
    pipelined: callable         # (k) -> result
    kernel: callable            # () -> result (kernel path, fixed k inside)
    helper: callable            # (k) -> result (decoupled two-pass)
    loop_body: callable | None = None   # (carry, x) for the DIL screen
    loop_init: object = None
    loop_xs: object = None
    check: callable | None = None


# ---------------------------------------------------------------------------
# 1. STLHistogram
# ---------------------------------------------------------------------------

def stl_histogram(p, seed=0) -> Workload:
    rng = np.random.default_rng(seed)
    uniq = rng.choice(1 << 30, size=p["histo_unique"],
                      replace=False).astype(np.int32)
    keys = rng.choice(uniq, size=p["histo_n"]).astype(np.int32)
    S = p["slots"]
    table = build_table(uniq, np.arange(len(uniq), dtype=np.int32), S,
                        WINDOW, LINE)
    tj = jnp.asarray(table)
    kj = jnp.asarray(keys)

    def probe_slot(key):
        """Resolve key -> slot id via the bounded probe window (the DIL:
        a window of table rows at a hashed address)."""
        start = bucket_of(key, S, WINDOW)
        offs = jnp.arange(WINDOW, dtype=jnp.int32)
        wkeys = jnp.take(tj[:, 0], start + offs)        # irregular gather
        hit = wkeys == key
        return start + jnp.argmax(hit), hit.any()

    def body(counts, key):
        slot, found = probe_slot(key)
        counts = counts.at[slot].add(
            jnp.where(found, 1, 0).astype(counts.dtype))
        return counts, None

    counts0 = jnp.zeros((S,), jnp.int32)

    @jax.jit
    def baseline():
        out, _ = jax.lax.scan(body, counts0, kj)
        return out

    def pipelined(k):
        @jax.jit
        def run():
            out, _ = pipeline.prefetch_scan(body, counts0, kj,
                                            prefetch_distance=k,
                                            delinquent_bytes=1 << 19)
            return out
        return run

    @jax.jit
    def kernel():
        res = hash_probe(tj, kj, window=WINDOW, block=8, lookahead=8)
        slots = bucket_of(kj, S, WINDOW) + 0  # start
        # recover slot id from value: value column stores index into uniq;
        # count by slot via the probe result: use value as identity
        vals, found = res[:, 0], res[:, 1]
        return jnp.zeros((S,), jnp.int32).at[
            bucket_of(kj, S, WINDOW)].add(0) + _scatter_hist(
                tj, kj, vals, found, S)

    def helper(k):
        # pass 1 ("helper thread"): vectorised address+window gather
        @jax.jit
        def addresses():
            start = bucket_of(kj, S, WINDOW)
            offs = jnp.arange(WINDOW, dtype=jnp.int32)
            return start, jnp.take(tj[:, 0], start[:, None] + offs[None, :])

        @jax.jit
        def main(start, windows):
            hit = windows == kj[:, None]
            slot = start + jnp.argmax(hit, axis=1)
            add = hit.any(axis=1).astype(jnp.int32)
            return jnp.zeros((S,), jnp.int32).at[slot].add(add)

        def run():
            s, w = addresses()
            return main(s, w)
        return run

    def check(a, b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    return Workload("STLHistogram", p, baseline, pipelined, kernel, helper,
                    loop_body=body, loop_init=counts0, loop_xs=kj,
                    check=check)


def _scatter_hist(tj, kj, vals, found, S):
    start = bucket_of(kj, S, WINDOW)
    offs = jnp.arange(WINDOW, dtype=jnp.int32)
    wkeys = jnp.take(tj[:, 0], start[:, None] + offs[None, :])
    hit = wkeys == kj[:, None]
    slot = start + jnp.argmax(hit, axis=1)
    return jnp.zeros((S,), jnp.int32).at[slot].add(
        found.astype(jnp.int32))


# ---------------------------------------------------------------------------
# 2. PageRank (BGL analogue: padded-CSR gather of neighbour ranks)
# ---------------------------------------------------------------------------

def _random_graph(n, avg_deg, rng, max_deg=None):
    max_deg = max_deg or 2 * avg_deg
    deg = np.minimum(rng.poisson(avg_deg, size=n), max_deg)
    nbrs = np.full((n, max_deg), -1, np.int32)
    for i in range(n):
        if deg[i]:
            nbrs[i, :deg[i]] = rng.integers(0, n, size=deg[i])
    return nbrs


def pagerank(p, seed=1) -> Workload:
    rng = np.random.default_rng(seed)
    n, d = p["graph_nodes"], p["graph_deg"]
    nbrs = _random_graph(n, d, rng)
    nj = jnp.asarray(nbrs)
    deg = jnp.maximum((nbrs >= 0).sum(1), 1).astype(jnp.float32)
    ranks0 = jnp.full((n,), 1.0 / n, jnp.float32)
    contrib0 = np.asarray(ranks0 / deg).astype(np.float32)
    DAMP = 0.85
    M = nbrs.shape[1]

    def body(acc, inp):
        """One node's incoming-rank sum: gather neighbour contributions
        (the DIL: contrib[] indexed by adjacency — irregular)."""
        i, row = inp
        vals = jnp.take(jnp.asarray(contrib0), jnp.maximum(row, 0))
        vals = vals * (row >= 0)
        acc = acc.at[i].set((1 - DAMP) / n + DAMP * vals.sum())
        return acc, None

    idx = jnp.arange(n, dtype=jnp.int32)

    @jax.jit
    def baseline():
        out, _ = jax.lax.scan(body, jnp.zeros((n,), jnp.float32),
                              (idx, nj))
        return out

    def pipelined(k):
        @jax.jit
        def run():
            out, _ = pipeline.prefetch_scan(
                body, jnp.zeros((n,), jnp.float32), (idx, nj),
                prefetch_distance=k, delinquent_bytes=1 << 16)
            return out
        return run

    @jax.jit
    def kernel():
        feats = jnp.asarray(contrib0)[:, None] * jnp.ones((1, LINE))
        mean = csr_gather_mean(feats, nj, lookahead=8)[:, 0]
        cnt = (nj >= 0).sum(1).astype(jnp.float32)
        return (1 - DAMP) / n + DAMP * mean * cnt

    def helper(k):
        @jax.jit
        def addresses():
            return jnp.take(jnp.asarray(contrib0),
                            jnp.maximum(nj, 0)) * (nj >= 0)

        @jax.jit
        def main(vals):
            return (1 - DAMP) / n + DAMP * vals.sum(1)

        def run():
            return main(addresses())
        return run

    def check(a, b):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)

    return Workload("PageRank", p, baseline, pipelined, kernel, helper,
                    loop_body=body,
                    loop_init=jnp.zeros((n,), jnp.float32),
                    loop_xs=(idx, nj), check=check)


# ---------------------------------------------------------------------------
# 3. HashJoin (probe phase of an in-memory equi-join)
# ---------------------------------------------------------------------------

def hashjoin(p, seed=2) -> Workload:
    rng = np.random.default_rng(seed)
    S = p["slots"]
    build_keys = rng.choice(1 << 30, size=p["join_build"],
                            replace=False).astype(np.int32)
    payload = rng.integers(0, 1 << 20, size=p["join_build"]).astype(np.int32)
    probe_keys = np.concatenate([
        rng.choice(build_keys, size=p["join_probe"] // 2),
        rng.integers(1 << 30, (1 << 31) - 1,
                     size=p["join_probe"] - p["join_probe"] // 2,
                     ).astype(np.int32)]).astype(np.int32)
    rng.shuffle(probe_keys)
    table = build_table(build_keys, payload, S, WINDOW, LINE)
    tj, pj = jnp.asarray(table), jnp.asarray(probe_keys)

    def body(acc, key):
        start = bucket_of(key, S, WINDOW)
        offs = jnp.arange(WINDOW, dtype=jnp.int32)
        win = jnp.take(tj, start + offs, axis=0)          # the DIL
        hit = win[:, 0] == key
        val = jnp.where(hit.any(),
                        jnp.max(jnp.where(hit, win[:, 1], -2**31 + 1)), 0)
        return (acc[0] + val.astype(jnp.int32),
                acc[1] + hit.any().astype(jnp.int32)), None

    init = (jnp.int32(0), jnp.int32(0))

    @jax.jit
    def baseline():
        out, _ = jax.lax.scan(body, init, pj)
        return out

    def pipelined(k):
        @jax.jit
        def run():
            out, _ = pipeline.prefetch_scan(body, init, pj,
                                            prefetch_distance=k,
                                            delinquent_bytes=1 << 19)
            return out
        return run

    @jax.jit
    def kernel():
        res = hash_probe(tj, pj, window=WINDOW, block=8, lookahead=8)
        vals = jnp.where(res[:, 1] == 1, res[:, 0], 0)
        return vals.astype(jnp.int32).sum(), res[:, 1].sum()

    def helper(k):
        @jax.jit
        def addresses():
            start = bucket_of(pj, S, WINDOW)
            offs = jnp.arange(WINDOW, dtype=jnp.int32)
            return jnp.take(tj, start[:, None] + offs[None, :], axis=0)

        @jax.jit
        def main(win):
            hit = win[:, :, 0] == pj[:, None]
            vals = jnp.where(hit.any(1),
                             jnp.max(jnp.where(hit, win[:, :, 1],
                                               -2**31 + 1), axis=1), 0)
            return vals.astype(jnp.int32).sum(), hit.any(1).sum(
                dtype=jnp.int32)

        def run():
            return main(addresses())
        return run

    def check(a, b):
        assert int(a[0]) == int(b[0]) and int(a[1]) == int(b[1])

    return Workload("HashJoin", p, baseline, pipelined, kernel, helper,
                    loop_body=body, loop_init=init, loop_xs=pj, check=check)


# ---------------------------------------------------------------------------
# 4. Graph500CSR (one BFS level expansion over the frontier)
# ---------------------------------------------------------------------------

def graph500(p, seed=3) -> Workload:
    rng = np.random.default_rng(seed)
    n, d = p["graph_nodes"], p["graph_deg"]
    nbrs = _random_graph(n, d, rng)
    nj = jnp.asarray(nbrs)
    frontier = jnp.asarray(rng.choice(n, size=n // 4,
                                      replace=False).astype(np.int32))
    M = nbrs.shape[1]

    def body(next_mask, node):
        row = jnp.take(nj, node, axis=0)               # the DIL: adjacency
        valid = row >= 0
        next_mask = next_mask.at[jnp.maximum(row, 0)].max(
            valid.astype(jnp.int32))
        return next_mask, None

    mask0 = jnp.zeros((n,), jnp.int32)

    @jax.jit
    def baseline():
        out, _ = jax.lax.scan(body, mask0, frontier)
        return out

    def pipelined(k):
        @jax.jit
        def run():
            out, _ = pipeline.prefetch_scan(body, mask0, frontier,
                                            prefetch_distance=k,
                                            delinquent_bytes=1 << 16)
            return out
        return run

    @jax.jit
    def kernel():
        rows = prefetch_gather(nj, frontier, block_rows=8, lookahead=8)
        valid = rows >= 0
        return jnp.zeros((n,), jnp.int32).at[
            jnp.maximum(rows, 0).reshape(-1)].max(
                valid.astype(jnp.int32).reshape(-1))

    def helper(k):
        @jax.jit
        def addresses():
            return jnp.take(nj, frontier, axis=0)

        @jax.jit
        def main(rows):
            valid = rows >= 0
            return jnp.zeros((n,), jnp.int32).at[
                jnp.maximum(rows, 0).reshape(-1)].max(
                    valid.astype(jnp.int32).reshape(-1))

        def run():
            return main(addresses())
        return run

    def check(a, b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    return Workload("Graph500CSR", p, baseline, pipelined, kernel, helper,
                    loop_body=body, loop_init=mask0, loop_xs=frontier,
                    check=check)


# ---------------------------------------------------------------------------
# 5. Cuckoo (NFV flow classification, two-choice hashing)
# ---------------------------------------------------------------------------

def cuckoo(p, seed=4) -> Workload:
    rng = np.random.default_rng(seed)
    S = p["slots"]
    flows = rng.choice(1 << 30, size=p["cuckoo_flows"],
                       replace=False).astype(np.int32)
    vals = rng.integers(0, 1 << 20, size=len(flows)).astype(np.int32)
    # two tables; each key lives in exactly one (insert-time choice)
    pick = rng.random(len(flows)) < 0.5
    t1 = build_table(flows[pick], vals[pick], S, WINDOW, LINE)
    # second hash: different multiplier via key rotation
    rot = np.bitwise_xor(flows[~pick], 0x5bd1e995).astype(np.int32)
    t2 = build_table(rot, vals[~pick], S, WINDOW, LINE)
    t1j, t2j = jnp.asarray(t1), jnp.asarray(t2)
    queries = jnp.asarray(rng.choice(flows, size=len(flows)))

    def probe(tab, key):
        start = bucket_of(key, S, WINDOW)
        offs = jnp.arange(WINDOW, dtype=jnp.int32)
        win = jnp.take(tab, start + offs, axis=0)
        hit = win[:, 0] == key
        return (jnp.where(hit.any(),
                          jnp.max(jnp.where(hit, win[:, 1], -2**31 + 1)),
                          -1),
                hit.any())

    def body(acc, key):
        v1, f1 = probe(t1j, key)                       # DIL #1
        v2, f2 = probe(t2j, jnp.bitwise_xor(key, 0x5bd1e995))  # DIL #2
        val = jnp.where(f1, v1, jnp.where(f2, v2, -1))
        return (acc[0] + jnp.maximum(val, 0).astype(jnp.int32),
                acc[1] + (f1 | f2).astype(jnp.int32)), None

    init = (jnp.int32(0), jnp.int32(0))

    @jax.jit
    def baseline():
        out, _ = jax.lax.scan(body, init, queries)
        return out

    def pipelined(k):
        @jax.jit
        def run():
            out, _ = pipeline.prefetch_scan(body, init, queries,
                                            prefetch_distance=k,
                                            delinquent_bytes=1 << 19)
            return out
        return run

    @jax.jit
    def kernel():
        r1 = hash_probe(t1j, queries, window=WINDOW, block=8, lookahead=8)
        r2 = hash_probe(t2j, jnp.bitwise_xor(queries, 0x5bd1e995),
                        window=WINDOW, block=8, lookahead=8)
        val = jnp.where(r1[:, 1] == 1, r1[:, 0],
                        jnp.where(r2[:, 1] == 1, r2[:, 0], -1))
        return (jnp.maximum(val, 0).astype(jnp.int32).sum(),
                ((r1[:, 1] == 1) | (r2[:, 1] == 1)).sum(dtype=jnp.int32))

    def helper(k):
        @jax.jit
        def addresses():
            offs = jnp.arange(WINDOW, dtype=jnp.int32)
            s1 = bucket_of(queries, S, WINDOW)
            q2 = jnp.bitwise_xor(queries, 0x5bd1e995)
            s2 = bucket_of(q2, S, WINDOW)
            return (jnp.take(t1j, s1[:, None] + offs, axis=0),
                    jnp.take(t2j, s2[:, None] + offs, axis=0), q2)

        @jax.jit
        def main(w1, w2, q2):
            h1 = w1[:, :, 0] == queries[:, None]
            h2 = w2[:, :, 0] == q2[:, None]
            v1 = jnp.where(h1.any(1), jnp.max(
                jnp.where(h1, w1[:, :, 1], -2**31 + 1), 1), -1)
            v2 = jnp.where(h2.any(1), jnp.max(
                jnp.where(h2, w2[:, :, 1], -2**31 + 1), 1), -1)
            val = jnp.where(h1.any(1), v1, jnp.where(h2.any(1), v2, -1))
            return (jnp.maximum(val, 0).astype(jnp.int32).sum(),
                    (h1.any(1) | h2.any(1)).sum(dtype=jnp.int32))

        def run():
            return main(*addresses())
        return run

    def check(a, b):
        assert int(a[0]) == int(b[0]) and int(a[1]) == int(b[1])

    return Workload("Cuckoo", p, baseline, pipelined, kernel, helper,
                    loop_body=body, loop_init=init, loop_xs=queries,
                    check=check)


WORKLOADS = {
    "STLHistogram": stl_histogram,
    "PageRank": pagerank,
    "HashJoin": hashjoin,
    "Graph500CSR": graph500,
    "Cuckoo": cuckoo,
}


def build(name: str, input_id: int = 1) -> Workload:
    return WORKLOADS[name](INPUTS[input_id])
