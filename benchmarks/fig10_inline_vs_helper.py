"""Fig 10 reproduction: inline prefetcher vs best-tuned helper thread.

Both schemes get their best tuning (as in the paper).  On the v5e cost
model:

* **inline**: per iteration max(iter_time, latency/k), best k from the
  fig7 sweep grid — no spawns, no extra memory traffic (the window lands
  in VMEM and is consumed in place);
* **helper**: best (spawn, skip) from the fig4 grid at the optimistic
  3 µs spawn cost, *plus* the decoupled-buffer tax: pass 1 must
  materialise every gathered window to HBM and pass 2 re-reads it
  (2 × window bytes per iteration of extra HBM traffic) — the TPU
  analogue of helper-thread cache interference the paper observes in
  the "All cores" mode.

Derived column: percent improvement of inline over helper — the paper's
headline is 13–83 % (Cuckoo outlier excluded).  Correctness of both
implementations is asserted against the baseline before modelling.
"""
from __future__ import annotations

import jax

from repro.core import planner

from . import workloads as W
from .fig4_helper_thread import SKIPS, helper_time_model, _iter_time
from .fig7_sweep import DISTANCES, expected_tpu_speedup
from .harness import csv_row

def inline_time_model(n: int, k: int, prof, hw=planner.V5E) -> float:
    t_iter = _iter_time(prof, hw)
    k_eff = min(k, prof["inner_trip"]) if prof["inner_trip"] else k
    return n * max(t_iter, hw.hbm_latency / max(k_eff, 1))


def helper_best_model(n: int, t_inline_best: float, prof,
                      hw=planner.V5E) -> float:
    """Same lookahead capability as inline (a helper can run no further
    ahead than its buffer, which we grant equal to the inline ring), so
    the difference is exactly the paper's causal claim: spawn overhead +
    the decoupled buffer round trip through HBM."""
    spawns = max(1, n // prof["alloc_epoch"])
    buffer_tax = n * 2 * prof["dil_bytes"] / hw.hbm_bw
    return t_inline_best + spawns * 3e-6 + buffer_tax


def run(input_id: int = 1) -> list[str]:
    rows = []
    for name in W.WORKLOADS:
        wl = W.build(name, input_id)
        ref = wl.baseline()
        wl.check(wl.pipelined(8)(), ref)
        wl.check(wl.helper(8)(), ref)
        n = _trip(wl)
        prof = W.PROFILES[name]
        t_inline = min(inline_time_model(n, k, prof) for k in DISTANCES)
        t_helper = helper_best_model(n, t_inline, prof)
        gain = (t_helper - t_inline) / t_helper * 100
        rows.append(csv_row(
            f"fig10.{name}.in{input_id}", t_inline,
            f"helper_us={t_helper * 1e6:.1f};"
            f"inline_gain_pct={gain:.1f}"))
    return rows


def _trip(wl) -> int:
    return int(jax.tree.leaves(wl.loop_xs)[0].shape[0])


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
