"""Benchmark entry point: one function per paper table/figure.

``python -m benchmarks.run [--quick]`` prints ``name,us_per_call,derived``
CSV rows for every reproduced artifact, plus the roofline table from any
dry-run results present.
"""
from __future__ import annotations

import sys


def main() -> None:
    quick = "--quick" in sys.argv
    from . import (fig1_stall_fraction, fig4_helper_thread, fig7_sweep,
                   fig9_uarch, fig10_inline_vs_helper, roofline,
                   table2_dil_screen)

    print("name,us_per_call,derived")
    for row in table2_dil_screen.run():
        if row.startswith("workload"):
            continue                       # header
        name, rest = row.split(",", 1)
        print(f"table2.{name},0.0,{rest.replace(',', ';')}")
    for row in fig1_stall_fraction.run():
        print(row)
    distances = [2, 8, 64] if quick else None
    names = ["STLHistogram", "HashJoin"] if quick else None
    for row in fig7_sweep.run(1, distances=distances, names=names):
        print(row)
    if not quick:
        for row in fig7_sweep.run(2, distances=[2, 8, 64, 256]):
            print(row)
    for row in fig4_helper_thread.run():
        print(row)
    for row in fig10_inline_vs_helper.run():
        print(row)
    for row in fig9_uarch.run():
        print(row)
    try:
        for mesh in ("single", "multi"):
            for row in roofline.table(mesh=mesh):
                print(f"roofline.{mesh}," + row)
    except Exception as e:  # dry-run results not generated yet
        print(f"roofline.unavailable,0.0,{type(e).__name__}")


if __name__ == "__main__":
    main()
