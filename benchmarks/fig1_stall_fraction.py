"""Fig 1 analogue: fraction of memory time attributable to the DILs.

The paper measures the fraction of CPU cycles stalled on specific
delinquent irregular loads.  The TPU analogue from the roofline model:
the fraction of each workload's memory-bound time spent on the
irregular gather traffic (bytes moved by the DIL vs total), computed
from the workload's access pattern — i.e. "how much of this loop's
memory time could an ideal prefetcher hide".
"""
from __future__ import annotations

import jax
import numpy as np

from . import workloads as W
from .harness import csv_row

LINE_BYTES = W.WINDOW * W.LINE * 4


def run(input_id: int = 1) -> list[str]:
    rows = []
    for name in W.WORKLOADS:
        wl = W.build(name, input_id)
        n = int(jax.tree.leaves(wl.loop_xs)[0].shape[0])
        # per-iteration traffic: streamed key/ids (regular) vs the
        # irregular window/row gather (the DIL)
        regular = 8.0                        # key + index stream bytes
        irregular = float(LINE_BYTES)
        frac = irregular / (regular + irregular)
        rows.append(csv_row(f"fig1.{name}.in{input_id}", 0.0,
                            f"dil_mem_fraction={frac:.2f};iters={n}"))
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
