"""Fig 4 reproduction: helper-"thread" tuning (spawn cost × skip fraction).

TPUs have no SMT contexts, so the helper-thread baseline is the closest
implementable decoupled analogue: the backward slice runs as a separate
dispatch (pass 1: addresses + windows) feeding the main pass, and every
dispatch boundary pays the paper's measured clone(2) spawn cost of
3–30 µs.  The ``skip`` parameter reproduces the paper's tunable start
delay (iterations processed before helpers start run un-helped).

Evaluation is on the v5e cost model (the same model as fig7's tpu_model
— serial HBM round trip per un-helped iteration, latency hidden for
helped iterations), with spawn events tied to allocation epochs exactly
as in the paper (§3.1: helpers are torn down around allocation; we use
one spawn per 256-iteration epoch of helped execution).  The *measured*
CPU decoupled pass is also validated for output correctness.

Reproduced observations: low skip => spawn-dominated; high skip => lost
opportunity; the optimum sits mid-range and moves with the input — the
paper's "tricky to tune" conclusion.
"""
from __future__ import annotations

import jax

from repro.core import planner

from . import workloads as W
from .harness import csv_row

SPAWN_COSTS_US = [3.0, 30.0]
SKIPS = [0.0, 0.25, 0.5, 0.75, 0.875]


def _iter_time(prof, hw=planner.V5E) -> float:
    return planner.iter_time(prof["iter_flops"],
                             prof["iter_bytes"] + prof["dil_bytes"], hw)


def helper_time_model(n: int, skip: float, spawn_us: float, prof,
                      hw=planner.V5E) -> float:
    t_iter = _iter_time(prof, hw)
    helped = int((1.0 - skip) * n)
    unhelped = n - helped
    spawns = max(1, helped // prof["alloc_epoch"])
    return (unhelped * (t_iter + hw.hbm_latency)     # serial misses
            + helped * t_iter                        # latency hidden
            + spawns * spawn_us * 1e-6)              # spawn overhead


def baseline_time_model(n: int, prof, hw=planner.V5E) -> float:
    return n * (_iter_time(prof, hw) + hw.hbm_latency)


def run(input_id: int = 1, names=("STLHistogram", "HashJoin")) -> list[str]:
    rows = []
    for name in names:
        wl = W.build(name, input_id)
        wl.check(wl.helper(8)(), wl.baseline())   # decoupled pass is exact
        n = _trip(wl)
        prof = W.PROFILES[name]
        t_base = baseline_time_model(n, prof)
        for spawn_us in SPAWN_COSTS_US:
            for skip in SKIPS:
                t = helper_time_model(n, skip, spawn_us, prof)
                rows.append(csv_row(
                    f"fig4.{name}.spawn{spawn_us:g}us.skip{skip:g}"
                    f".in{input_id}", t,
                    f"helper_speedup_model={t_base / t:.3f}"))
    return rows


def _trip(wl) -> int:
    return int(jax.tree.leaves(wl.loop_xs)[0].shape[0])


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
