"""Fig 7 / Fig 8 reproduction: prefetch-distance sweep per workload.

For each workload: baseline (unmodified scan) vs inline-prefetch rewrite
at k ∈ {2..256} powers of two, plus the Pallas-kernel path.

This container is CPU-only, and an XLA scan on one core has no async
memory path — so wall-clock here measures the *cost* of the rewrite,
not its benefit.  The two derived numbers split the paper's figure
faithfully:

* ``cpu_overhead`` — measured: extra work added by the duplicated
  backward slice + ring bookkeeping (the analogue of the paper's
  Fig 7b dynamic-instruction overhead; the paper's own speedups are
  *net of* this overhead);
* ``tpu_model``   — the v5e roofline model of Fig 7a: the baseline pays
  one serial HBM round trip per iteration, the pipelined loop pays
  max(iter_time, latency/k); small-trip-count lost opportunity included
  (the PageRank/Cuckoo effect of §5.2.2).

Outputs correctness too: every variant's result is asserted identical
to the baseline before timing (paper §4.2's exact-output requirement).
"""
from __future__ import annotations

import jax

from repro.core import planner

from . import workloads as W
from .harness import csv_row, time_fn

DISTANCES = [2, 4, 8, 16, 32, 64, 128, 256]


def expected_tpu_speedup(row_bytes: int, iter_flops: float,
                         iter_bytes: float, k: int,
                         trip: int | None = None) -> float:
    """Roofline model of the paper's mechanism on v5e: the baseline pays
    one HBM latency per iteration (serial dependent gather); the
    pipelined version pays max(iter_time, latency/k) — the prefetch
    distance amortises the round trip across k in-flight DMAs."""
    hw = planner.V5E
    t_iter = planner.iter_time(iter_flops, iter_bytes + row_bytes, hw)
    t_base = t_iter + hw.hbm_latency
    t_pf = max(t_iter, hw.hbm_latency / max(k, 1))
    if trip is not None and k > trip:       # lookahead beyond trip count
        t_pf = t_base                       # lost opportunity (paper §5.2.2)
    return t_base / max(t_pf, 1e-12)


def run(input_id: int = 1, distances=None, names=None) -> list[str]:
    rows = []
    for name in (names or W.WORKLOADS):
        wl = W.build(name, input_id)
        base = wl.baseline
        ref = base()
        t_base = time_fn(base)
        rows.append(csv_row(f"fig7.{name}.baseline.in{input_id}", t_base,
                            "speedup=1.00"))
        n_iter = _trip_count(wl)
        prof = W.PROFILES[name]
        trip = prof["inner_trip"] or n_iter
        for k in (distances or DISTANCES):
            fn = wl.pipelined(k)
            out = fn()
            wl.check(out, ref)
            t = time_fn(fn)
            exp = expected_tpu_speedup(
                row_bytes=prof["dil_bytes"], iter_flops=prof["iter_flops"],
                iter_bytes=prof["iter_bytes"], k=k, trip=trip)
            rows.append(csv_row(
                f"fig7.{name}.k{k}.in{input_id}", t,
                f"cpu_overhead={t / t_base:.2f};tpu_model={exp:.2f}"))
        kfn = wl.kernel
        out = kfn()
        wl.check(out, ref)
        t = time_fn(kfn)
        rows.append(csv_row(f"fig7.{name}.kernel.in{input_id}", t,
                            "interpret_mode=1"))
    return rows


def _trip_count(wl) -> int | None:
    xs = jax.tree.leaves(wl.loop_xs)
    return int(xs[0].shape[0]) if xs else None


def main(input_id: int = 1):
    for r in run(input_id):
        print(r)


if __name__ == "__main__":
    import sys
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 1)
