"""Batched serving with a paged KV cache (the serving-side DIL).

Prefills a batch of prompts on a reduced qwen3-family model, decodes
greedily, and demonstrates the paged_kv inline-prefetch kernel scoring
one decode step against a paged pool (page table indirection =
``pool[page_table[b, p]]``, the paper's a[b[i]] pattern).

Run:  PYTHONPATH=src python examples/serve_paged.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

import repro.models as models
from repro.configs import get_arch, reduced
from repro.kernels import paged_attn_scores, paged_attn_scores_ref
from repro.serving import greedy_generate

cfg = reduced(get_arch("qwen3-8b"), n_layers=2, d_model=64, n_heads=4,
              n_kv_heads=2, d_ff=128, vocab=512)
params = models.init_params(cfg, jax.random.PRNGKey(0))

B, S, n_new = 4, 12, 8
prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                             cfg.vocab_size)
toks = greedy_generate(cfg, params, prompts, n_new)
print(f"served batch of {B}: prompts {S} tokens -> +{n_new} greedy tokens")
print(np.asarray(toks))

# --- paged KV scoring with the inline-prefetch kernel -----------------------
rng = np.random.default_rng(0)
pool = rng.standard_normal((64, 16, 32)).astype(np.float32)   # 64 pages
page_table = rng.integers(0, 64, size=(B, 4)).astype(np.int32)
q = rng.standard_normal((B, 32)).astype(np.float32)
scores = paged_attn_scores(pool, page_table, q, lookahead=4)
ref = paged_attn_scores_ref(jnp.asarray(pool), jnp.asarray(page_table),
                            jnp.asarray(q))
np.testing.assert_allclose(np.asarray(scores), np.asarray(ref), rtol=1e-4,
                           atol=1e-4)
print(f"paged_kv kernel scores {scores.shape}: match ref (page-table "
      "indirection prefetched 4 pages ahead)")
