"""End-to-end driver: train a ~100M-parameter LM with the full runtime
(data pipeline -> pjit train step -> checkpointing -> watchdog).

Default is a quick demonstration (--steps 20); pass --steps 300 for the
full few-hundred-step run (CPU-bound in this container; the same driver
is what launch/train.py runs on a real mesh).

Run:  PYTHONPATH=src python examples/train_100m.py --steps 20
"""
import argparse
import sys

sys.path.insert(0, "src")

import dataclasses

from repro.configs import get_arch
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, Trainer


def cfg_100m():
    base = get_arch("qwen3-8b")
    return dataclasses.replace(
        base, name="qwen3-100m", n_layers=12, d_model=640, n_heads=10,
        n_kv_heads=5, d_head=64, d_ff=2560, vocab_size=32768, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = cfg_100m()
    print(f"model: {cfg.name}  params={cfg.param_count() / 1e6:.1f}M")
    tr = Trainer(cfg, TrainConfig(microbatches=1, grad_compression=True,
                                  peak_lr=3e-4, warmup=20, ckpt_every=50,
                                  adamw=AdamWConfig(lr=3e-4)),
                 make_local_mesh(), seq_len=args.seq,
                 global_batch=args.batch, ckpt_dir=args.ckpt)
    if tr.step:
        print(f"resumed from checkpoint at step {tr.step}")
    hist = tr.run(args.steps, log_every=5)
    for step, loss, dt in hist:
        print(f"step {step:>4}  loss {loss:.4f}  {dt * 1e3:.0f} ms")
    print("watchdog healthy:", tr.watchdog.healthy())


if __name__ == "__main__":
    main()
