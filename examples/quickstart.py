"""Quickstart: the paper's technique in five minutes.

1. Write an irregular-gather loop (hash-table histogram).
2. Run the DIL screen — see the load classified prefetchable.
3. Swap lax.scan for repro.core.prefetch_scan — bit-identical results,
   with the gather hoisted k iterations ahead (the carrot-and-horse
   schedule of the paper, Fig 6).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dil, pipeline, planner

N = 1 << 18
rng = np.random.default_rng(0)
table = rng.standard_normal((N, 8)).astype(np.float32)   # 8 MiB, HBM-class
keys = rng.integers(0, 1 << 30, size=4096).astype(np.int32)


def body(carry, key):
    """The paper's Listing-1 shape: hash -> irregular gather -> reduce."""
    i, acc = carry
    idx = (key * 40503) % N                  # hash (irregular by design)
    row = jnp.take(table, idx, axis=0)       # the DIL
    return (i + 1, acc + row.sum()), None


init = (jnp.int32(0), jnp.float32(0))

# -- 2. the DIL screen -------------------------------------------------------
report = dil.screen_loop(body, init, keys[0])
print("DIL screen:")
print(report.summary())

# -- 3. carrot-and-horse rewrite --------------------------------------------
k = planner.plan_prefetch_distance(row_bytes=8 * 4, flops_per_iter=16,
                                   hbm_bytes_per_iter=4)
print(f"\nplanned prefetch distance k = {k}")

ref, _ = jax.lax.scan(body, init, keys)
opt, _ = pipeline.prefetch_scan(body, init, keys, prefetch_distance=k)
np.testing.assert_array_equal(np.asarray(ref[1]), np.asarray(opt[1]))
print(f"baseline == prefetched: {float(ref[1]):.4f} (bit-exact)")
