"""PageRank with inline-prefetched neighbour gathers (paper §5 workload).

Runs power iterations where each iteration's rank gather is the DIL;
compares the naive loop, the carrot-and-horse rewrite and the Pallas
csr_gather kernel path.

Run:  PYTHONPATH=src python examples/pagerank.py
"""
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import workloads as W
from repro.kernels import csr_gather_mean

rng = np.random.default_rng(0)
n, avg_deg, iters = 4096, 6, 10
nbrs = W._random_graph(n, avg_deg, rng)
nj = jnp.asarray(nbrs)
deg = jnp.maximum((nbrs >= 0).sum(1), 1).astype(jnp.float32)
DAMP = 0.85


@jax.jit
def power_iteration(ranks):
    contrib = (ranks / deg)[:, None] * jnp.ones((1, 8))
    mean = csr_gather_mean(contrib, nj, lookahead=8)[:, 0]
    cnt = (nj >= 0).sum(1).astype(jnp.float32)
    return (1 - DAMP) / n + DAMP * mean * cnt


@jax.jit
def power_iteration_ref(ranks):
    contrib = ranks / deg
    vals = jnp.take(contrib, jnp.maximum(nj, 0)) * (nj >= 0)
    return (1 - DAMP) / n + DAMP * vals.sum(1)


r_k = r_ref = jnp.full((n,), 1.0 / n, jnp.float32)
for i in range(iters):
    r_k, r_ref = power_iteration(r_k), power_iteration_ref(r_ref)
np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_ref), rtol=1e-5)
top = np.argsort(np.asarray(r_ref))[-5:][::-1]
print(f"PageRank converged over {iters} iterations (kernel == ref).")
print("top-5 nodes:", top.tolist())
print("top-5 ranks:", np.round(np.asarray(r_ref)[top], 6).tolist())
