"""STLHistogram end-to-end (paper §3/§5): screen, rewrite, sweep k.

Run:  PYTHONPATH=src python examples/histogram.py
"""
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax
import numpy as np

from benchmarks import workloads as W
from benchmarks.harness import time_fn
from repro.core import dil

wl = W.build("STLHistogram", 1)

# The screen on the histogram loop (Table 2 row)
rep = dil.screen_loop(wl.loop_body, wl.loop_init,
                      jax.tree.map(lambda a: a[0], wl.loop_xs),
                      delinquent_bytes=1 << 16)
print("DIL screen (STLHistogram):")
print(rep.summary())

ref = wl.baseline()
t_base = time_fn(wl.baseline, runs=3, warmup=1)
print(f"\nbaseline: {t_base * 1e6:.0f} us")
for k in (2, 8, 32, 128):
    fn = wl.pipelined(k)
    wl.check(fn(), ref)
    t = time_fn(fn, runs=3, warmup=1)
    print(f"prefetch k={k:<4}: {t * 1e6:.0f} us  "
          f"(speedup {t_base / t:.2f}x, output exact)")
kt = time_fn(wl.kernel, runs=3, warmup=1)
wl.check(wl.kernel(), ref)
print(f"pallas hash_probe kernel: {kt * 1e6:.0f} us (interpret mode)")
