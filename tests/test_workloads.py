"""Paper-workload integration tests: every implementation variant of
every workload produces identical results, and the DIL screen certifies
each hot loop (Table 2)."""
import sys

import jax
import pytest

sys.path.insert(0, ".")  # benchmarks package lives at repo root

from benchmarks import workloads as W  # noqa: E402
from repro.core import dil  # noqa: E402


@pytest.fixture(scope="module", params=list(W.WORKLOADS))
def wl(request):
    return W.build(request.param, 1)


def test_pipelined_matches_baseline(wl):
    ref = wl.baseline()
    for k in (2, 16, 128):
        wl.check(wl.pipelined(k)(), ref)


def test_kernel_matches_baseline(wl):
    wl.check(wl.kernel(), wl.baseline())


def test_helper_matches_baseline(wl):
    wl.check(wl.helper(8)(), wl.baseline())


def test_screen_finds_prefetchable_dil(wl):
    x0 = jax.tree.map(lambda a: a[0], wl.loop_xs)
    rep = dil.screen_loop(wl.loop_body, wl.loop_init, x0,
                          delinquent_bytes=1 << 16)
    assert rep.critical_targets, rep.summary()


def test_input2_scales():
    wl2 = W.build("STLHistogram", 2)
    assert wl2.data["histo_n"] > W.INPUTS[1]["histo_n"]
    wl2.check(wl2.pipelined(8)(), wl2.baseline())
