"""Elastic re-mesh: a checkpoint written on one mesh restores onto a
different device count/shape.  The restore path device_puts each leaf
with the *target* sharding, so re-meshing is pure load-time work — this
is the recovery half of the straggler/elastic story (runtime/ft.py).

Runs in a subprocess with 4 forced host-platform devices (the parent
session must keep seeing 1 device).
"""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import AxisType

    import repro.models as models
    from repro.checkpoint import restore, save
    from repro.configs import get_arch, reduced
    from repro.parallel import make_shardings, param_pspecs

    assert len(jax.devices()) == 4
    cfg = reduced(get_arch("qwen3-8b"), n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=128)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    ckpt = sys.argv[1]

    # write on a (1,1) "mesh" (single-host layout)
    save(ckpt, 1, params)

    # restore onto a 2x2 production-style mesh with proper shardings
    mesh = jax.make_mesh((2, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    shardings = make_shardings(param_pspecs(params, mesh), mesh)
    restored = restore(ckpt, 1, params, shardings)

    leaf = restored["units"][0]["attn"]["wq"]["w"]
    assert len(leaf.sharding.device_set) == 4, leaf.sharding
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # and the restored (resharded) params still serve
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32)}
    with mesh:
        logits, _, _ = models.transformer.forward(restored, batch, cfg)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print("ELASTIC_OK")
""")


def test_restore_onto_larger_mesh(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(tmp_path / "ck")],
        capture_output=True, text=True, timeout=300, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "ELASTIC_OK" in out.stdout, out.stdout + out.stderr
