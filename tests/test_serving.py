"""Serving engine: prefill+decode consistency and batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as models
from repro.configs import get_arch, reduced
from repro.serving import ServeEngine, greedy_generate

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def lm():
    cfg = reduced(get_arch("qwen3-8b"), n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=256)
    return cfg, models.init_params(cfg, KEY)


def test_greedy_matches_teacher_forcing(lm):
    """Tokens decoded with the KV cache must equal argmax of a full
    forward pass over the generated prefix (cache correctness e2e)."""
    cfg, params = lm
    B, S, n_new = 2, 8, 6
    prompt = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    toks = np.asarray(greedy_generate(cfg, params, prompt, n_new))
    seq = np.asarray(prompt)
    for t in range(n_new):
        full = jnp.asarray(np.concatenate([seq, toks[:, :t]], axis=1))
        logits, _, _ = models.transformer.forward(
            params, {"tokens": full}, cfg)
        expect = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        np.testing.assert_array_equal(toks[:, t], expect)


def test_batched_decode_is_per_sequence_consistent(lm):
    """Each sequence in a batch decodes as it would alone."""
    cfg, params = lm
    prompt = jax.random.randint(KEY, (3, 8), 0, cfg.vocab_size)
    both = np.asarray(greedy_generate(cfg, params, prompt, 4))
    solo = np.asarray(greedy_generate(cfg, params, prompt[1:2], 4))
    np.testing.assert_array_equal(both[1:2], solo)


def test_engine_capacity_independent(lm):
    cfg, params = lm
    prompt = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    a = np.asarray(greedy_generate(cfg, params, prompt, 4, capacity=16))
    b = np.asarray(greedy_generate(cfg, params, prompt, 4, capacity=64))
    np.testing.assert_array_equal(a, b)
