"""End-to-end training-loop tests on a tiny model: loss goes down,
checkpoint/restart resumes exactly, microbatching matches full batch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as models
from repro.configs import get_arch, reduced
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, Trainer, make_train_step


def tiny_cfg():
    return reduced(get_arch("qwen3-8b"), n_layers=2, d_model=32, n_heads=2,
                   n_kv_heads=2, d_ff=64, vocab=128)


def tcfg(**kw):
    base = dict(microbatches=1, grad_compression=False, peak_lr=3e-3,
                warmup=5, ckpt_every=5, adamw=AdamWConfig(lr=3e-3))
    base.update(kw)
    return TrainConfig(**base)


class TestTrainer:
    def test_loss_decreases(self, tmp_path):
        tr = Trainer(tiny_cfg(), tcfg(), make_local_mesh(), seq_len=16,
                     global_batch=4, ckpt_dir=None)
        hist = tr.run(30, log_every=1)
        first, last = hist[0][1], hist[-1][1]
        assert last < first - 0.1, (first, last)

    def test_checkpoint_restart_resumes(self, tmp_path):
        kw = dict(seq_len=16, global_batch=4, seed=1)
        a = Trainer(tiny_cfg(), tcfg(), make_local_mesh(),
                    ckpt_dir=str(tmp_path / "ck"), **kw)
        a.run(10)                                   # checkpoints at 5, 10
        params_at_10 = jax.tree.map(np.asarray, a.params)
        # simulated crash: new trainer on same dir resumes from step 10
        b = Trainer(tiny_cfg(), tcfg(), make_local_mesh(),
                    ckpt_dir=str(tmp_path / "ck"), **kw)
        assert b.step == 10
        for x, y in zip(jax.tree.leaves(params_at_10),
                        jax.tree.leaves(b.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_microbatch_equivalence(self):
        """mb=2 gradient accumulation == mb=1 on the same batch."""
        cfg = tiny_cfg()
        params = models.init_params(cfg, jax.random.PRNGKey(0))
        from repro.optim import adamw_init, init_error_feedback
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                         0, cfg.vocab_size),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16),
                                         0, cfg.vocab_size),
        }
        outs = []
        for mb in (1, 2):
            step = make_train_step(cfg, tcfg(microbatches=mb))
            p, o, r = (params, adamw_init(params),
                       init_error_feedback(params))
            p2, _, _, m = jax.jit(step)(p, o, r, batch, jnp.int32(100))
            outs.append((jax.tree.map(np.asarray, p2), float(m["loss"])))
        assert outs[0][1] == pytest.approx(outs[1][1], rel=1e-3)
        # bf16 params + f32 accumulation: tolerate one-ulp straddles
        for x, y in zip(jax.tree.leaves(outs[0][0]),
                        jax.tree.leaves(outs[1][0])):
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32),
                                       rtol=5e-2, atol=4e-3)

    def test_grad_compression_trains(self):
        tr = Trainer(tiny_cfg(), tcfg(grad_compression=True),
                     make_local_mesh(), seq_len=16, global_batch=4)
        hist = tr.run(20, log_every=1)
        assert hist[-1][1] < hist[0][1]

    def test_watchdog_is_fed(self):
        tr = Trainer(tiny_cfg(), tcfg(), make_local_mesh(), seq_len=8,
                     global_batch=2)
        tr.run(3)
        assert tr.watchdog._ewma           # observed step times
