"""DIL screen unit tests: the four canonical patterns of the paper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dil

N = 1 << 18
TABLE = np.arange(4 * N, dtype=np.float32).reshape(N, 4)   # 4 MiB
NXT = np.random.default_rng(0).permutation(N).astype(np.int32)
KEYS = np.random.default_rng(1).random(N, dtype=np.float32)
DELINQ = 1 << 20


def _screen(body, carry, x):
    return dil.screen_loop(body, carry, x, delinquent_bytes=DELINQ)


class TestClassification:
    def test_hash_index_is_prefetchable(self):
        def body(c, x):
            i, acc = c
            idx = (x * 40503) % N
            return (i + 1, acc + jnp.take(TABLE, idx, axis=0).sum()), None
        r = _screen(body, (jnp.int32(0), jnp.float32(0)), jnp.int32(3))
        (load,) = r.loads
        assert load.index_class == dil.IRREGULAR
        assert load.delinquent and load.runnable and load.control_independent
        assert load.prefetchable and load.critical

    def test_striding_load_left_to_hardware(self):
        def body(c, x):
            i, acc = c
            return (i + 2, acc + jnp.take(TABLE, i, axis=0).sum()), None
        r = _screen(body, (jnp.int32(0), jnp.float32(0)), jnp.int32(0))
        (load,) = r.loads
        assert load.index_class == dil.STRIDING
        assert not load.prefetchable

    def test_pointer_chase_is_chasing(self):
        def body(c, x):
            idx, acc = c
            idx2 = jnp.take(NXT, idx)
            row = jnp.take(TABLE, idx2, axis=0)
            return (idx2, acc + row.sum()), None
        r = _screen(body, (jnp.int32(0), jnp.float32(0)), jnp.int32(0))
        assert all(not l.runnable for l in r.loads if l.index_class ==
                   dil.IRREGULAR)
        assert not r.prefetchable

    def test_bst_descent_excluded(self):
        def body(c, x):
            idx, acc = c
            v = jnp.take(KEYS, idx)
            nxt = jnp.where(v < x, 2 * idx + 1, 2 * idx + 2) % N
            return (nxt, acc + v), None
        r = _screen(body, (jnp.int32(0), jnp.float32(0)), jnp.float32(0.5))
        assert not r.prefetchable

    def test_small_table_not_delinquent(self):
        small = np.zeros((16, 4), np.float32)

        def body(c, x):
            i, acc = c
            idx = (x * 7) % 16
            return (i + 1, acc + jnp.take(small, idx, axis=0).sum()), None
        r = _screen(body, (jnp.int32(0), jnp.float32(0)), jnp.int32(1))
        (load,) = r.loads
        assert load.index_class == dil.IRREGULAR and not load.delinquent
        assert not load.prefetchable

    def test_dependent_chain_is_prefetchable(self):
        feeder = np.arange(4096, dtype=np.int32)

        def body(c, _):
            i, acc = c
            b = jnp.take(feeder, i)              # striding feeder
            idx = (b * 7 + 3) % N                # f(b[i])
            return (i + 1, acc + jnp.take(TABLE, idx, axis=0).sum()), None
        r = _screen(body, (jnp.int32(0), jnp.float32(0)), None)
        big = [l for l in r.loads if l.table_bytes >= DELINQ]
        assert len(big) == 1 and big[0].prefetchable

    def test_coalescing_same_cache_line(self):
        def body(c, x):
            i, acc = c
            idx = (x * 40503) % (N - 1)
            a = jnp.take(TABLE, idx, axis=0).sum()
            b = jnp.take(TABLE, idx + 1, axis=0).sum()   # same-line offset
            return (i + 1, acc + a + b), None
        r = _screen(body, (jnp.int32(0), jnp.float32(0)), jnp.int32(3))
        assert len(r.prefetchable) == 2
        assert len(r.critical_targets) == 1


class TestDynamicDeltas:
    def test_hash_deltas_irregular(self):
        def body(c, x):
            i, acc = c
            idx = (x * 40503) % N
            return (i + 1, acc + jnp.take(TABLE, idx, axis=0).sum()), None
        r = _screen(body, (jnp.int32(0), jnp.float32(0)), jnp.int32(3))
        xs = np.random.default_rng(2).integers(
            0, 1 << 30, size=128).astype(np.int32)
        h = dil.delta_histogram(r, r.loads[0],
                                (jnp.int32(0), jnp.float32(0)), xs, 128)
        assert dil.is_irregular_deltas(h)

    def test_stride_deltas_regular(self):
        def body(c, x):
            i, acc = c
            return (i + 2, acc + jnp.take(TABLE, i, axis=0).sum()), None
        r = _screen(body, (jnp.int32(0), jnp.float32(0)), jnp.int32(0))
        xs = np.zeros(64, np.int32)
        h = dil.delta_histogram(r, r.loads[0],
                                (jnp.int32(0), jnp.float32(0)), xs, 64)
        assert len(h) == 1 and not dil.is_irregular_deltas(h)


def test_screen_whole_function_finds_scan_loops():
    def hist(xs):
        def body(c, x):
            idx = (x * 40503) % N
            return c + jnp.take(TABLE, idx, axis=0).sum(), None
        out, _ = jax.lax.scan(body, jnp.float32(0), xs)
        return out

    rs = dil.screen(hist, jnp.arange(64, dtype=jnp.int32),
                    delinquent_bytes=DELINQ)
    assert len(rs) == 1
    (rep,) = rs.values()
    assert rep.critical_targets
