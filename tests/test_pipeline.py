"""Carrot-and-horse transform: bit-exactness vs lax.scan (paper §4.2's
"outputs must match exactly" requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.core import pipeline, planner

N = 1 << 16
RNG = np.random.default_rng(0)
TABLE = RNG.standard_normal((N, 8)).astype(np.float32)
DELINQ = 1 << 20


def hash_body(carry, x):
    i, acc = carry
    idx = (x * 40503) % N
    row = jnp.take(TABLE, idx, axis=0)
    return (i + 1, acc + row.sum()), row[0]


XS = RNG.integers(0, 1 << 30, size=257).astype(np.int32)
INIT = (jnp.int32(0), jnp.float32(0))


class TestPrefetchScan:
    @pytest.mark.parametrize("k", [1, 2, 3, 8, 64, 300])
    def test_exact_match_all_distances(self, k):
        ref_c, ref_y = lax.scan(hash_body, INIT, XS)
        c, y = pipeline.prefetch_scan(hash_body, INIT, XS,
                                      prefetch_distance=k,
                                      delinquent_bytes=DELINQ)
        np.testing.assert_array_equal(np.asarray(c[1]), np.asarray(ref_c[1]))
        np.testing.assert_array_equal(np.asarray(y), np.asarray(ref_y))

    def test_xs_none_striding_feeder(self):
        feeder = RNG.integers(0, N, size=512).astype(np.int32)

        def body(carry, _):
            i, acc = carry
            b = jnp.take(feeder, i)
            idx = (b * 7 + 3) % N
            return (i + 1, acc + jnp.take(TABLE, idx, axis=0).sum()), None

        ref, _ = lax.scan(body, INIT, None, length=200)
        got, _ = pipeline.prefetch_scan(body, INIT, None,
                                        prefetch_distance=16, length=200,
                                        delinquent_bytes=DELINQ)
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(ref[1]))

    def test_rejects_chasing(self):
        nxt = RNG.permutation(N).astype(np.int32)

        def body(carry, _):
            idx, acc = carry
            idx2 = jnp.take(nxt, idx)
            return (idx2, acc + jnp.take(TABLE, idx2, axis=0).sum()), None

        with pytest.raises(ValueError, match="no prefetchable DIL"):
            pipeline.prefetch_scan(body, INIT, None, length=10,
                                   delinquent_bytes=DELINQ)

    def test_rejects_regular(self):
        def body(carry, x):
            i, acc = carry
            return (i + 1, acc + jnp.take(TABLE, i, axis=0).sum()), None

        with pytest.raises(ValueError, match="no prefetchable DIL"):
            pipeline.prefetch_scan(body, INIT, XS, delinquent_bytes=DELINQ)

    def test_jit_compatible(self):
        @jax.jit
        def run(xs):
            c, _ = pipeline.prefetch_scan(hash_body, INIT, xs,
                                          prefetch_distance=8,
                                          delinquent_bytes=DELINQ)
            return c[1]

        ref_c, _ = lax.scan(hash_body, INIT, XS)
        np.testing.assert_array_equal(np.asarray(run(XS)),
                                      np.asarray(ref_c[1]))

    def test_grad_through_pipelined_scan(self):
        """The rewrite stays differentiable (it is pure JAX)."""
        def loss_ref(table):
            def body(c, x):
                idx = (x * 40503) % N
                return c + jnp.take(table, idx, axis=0).sum(), None
            out, _ = lax.scan(body, jnp.float32(0), XS[:64])
            return out

        g_ref = jax.grad(loss_ref)(jnp.asarray(TABLE))

        def loss_pf(table):
            def body(c, x):
                idx = (x * 40503) % N
                return c + jnp.take(table, idx, axis=0).sum(), None
            out, _ = pipeline.prefetch_scan(body, jnp.float32(0), XS[:64],
                                            prefetch_distance=8,
                                            delinquent_bytes=DELINQ)
            return out

        g_pf = jax.grad(loss_pf)(jnp.asarray(TABLE))
        np.testing.assert_allclose(np.asarray(g_pf), np.asarray(g_ref),
                                   rtol=1e-6)


class TestManualPipelinedScan:
    def test_matches_fused_loop(self):
        k = 8

        def carrot(i, x):
            return i + 1, (x * 40503) % N

        def gather(idx):
            return jnp.take(TABLE, idx, axis=0)

        def horse(acc, x, row):
            return acc + row.sum(), row[0]

        ref_acc = jnp.float32(0)
        outs = []
        for x in XS[:40].tolist():
            _, idx = carrot(0, jnp.int32(x))
            row = gather(idx)
            ref_acc, y = horse(ref_acc, x, row)
            outs.append(np.asarray(y))
        acc, ys = pipeline.pipelined_scan(
            carrot, gather, horse, jnp.int32(0), jnp.float32(0),
            jnp.asarray(XS[:40]), prefetch_distance=k)
        np.testing.assert_allclose(float(acc), float(ref_acc), rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(ys), np.stack(outs))


class TestPlanner:
    def test_latency_bound(self):
        k = planner.plan_prefetch_distance(
            row_bytes=512, flops_per_iter=1e4, hbm_bytes_per_iter=2048)
        assert k >= 2 and (k & (k - 1)) == 0   # power of two

    def test_vmem_bound(self):
        k = planner.plan_prefetch_distance(
            row_bytes=32 * 2**20, flops_per_iter=10, hbm_bytes_per_iter=10)
        assert k * 32 * 2**20 <= planner.V5E.vmem_bytes

    def test_trip_count_bound(self):
        k = planner.plan_prefetch_distance(
            row_bytes=512, flops_per_iter=10, hbm_bytes_per_iter=10,
            trip_count=6)
        assert k <= 6
