"""Sharding-rule tests: every parameter of every arch gets a pspec that
divides both production meshes (verified with AbstractMesh — no devices)."""
import jax
import jax.tree_util as jtu
import numpy as np
import pytest
from jax.sharding import AbstractMesh, AxisType, PartitionSpec as P

import repro.models as models
from repro.configs import ARCHS
from repro.parallel import sharding as sh

SINGLE = AbstractMesh((16, 16), ("data", "model"),
                      axis_types=(AxisType.Auto,) * 2)
MULTI = AbstractMesh((2, 16, 16), ("pod", "data", "model"),
                     axis_types=(AxisType.Auto,) * 3)


def _axis_size(mesh, axis):
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
@pytest.mark.parametrize("arch", list(ARCHS))
def test_param_pspecs_divide(arch, mesh):
    cfg = ARCHS[arch]
    avals = models.abstract_params(cfg)
    specs = sh.param_pspecs(avals, mesh)
    flat_a = jtu.tree_leaves(avals)
    flat_s = jtu.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_a) == len(flat_s)
    for aval, spec in zip(flat_a, flat_s):
        for dim, axis in zip(aval.shape, tuple(spec)):
            assert dim % _axis_size(mesh, axis) == 0, (aval.shape, spec)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_big_weights_are_2d_sharded(arch):
    """Every >=8 MiB weight must shard on BOTH model and data axes
    (fully-sharded discipline — anything replicated at 104B scale OOMs)."""
    cfg = ARCHS[arch]
    avals = models.abstract_params(cfg)
    specs = sh.param_pspecs(avals, SINGLE)
    flat = jtu.tree_flatten_with_path(avals)[0]
    spec_flat = jtu.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, aval), spec in zip(flat, spec_flat):
        nbytes = int(np.prod(aval.shape)) * aval.dtype.itemsize
        if nbytes >= 8 * 2**20:
            used = {a for a in jtu.tree_leaves(tuple(spec))}
            assert "model" in used or "data" in used, (path, spec)
            # every big weight must be sharded across the full 2-D mesh
            # (256-way) or at minimum 64-way — replication at 104B/132B
            # scale is what OOMs
            shards = np.prod([_axis_size(SINGLE, a) for a in tuple(spec)])
            assert shards >= 64, (path, spec, nbytes)


def test_cache_pspec_shards_kv_seq_on_model():
    cfg = ARCHS["qwen3-8b"]
    cache = jax.eval_shape(
        lambda: models.init_cache(cfg, 128, 32768))
    specs = sh.cache_pspecs(cache, SINGLE)
    # stacked cache: (n_units, B, S, Hkv, dh) — batch on data, S on model
    kv_spec = specs["units"][0]["kv"]["k"]
    assert kv_spec == P(None, "data", "model", None, None)


def test_batch_pspec_falls_back_on_batch_1():
    cache = {"x": jax.ShapeDtypeStruct((1, 64), np.float32)}
    specs = sh.batch_pspec(cache, SINGLE)
    assert specs["x"] == P(None, None)


def test_activation_hooks_noop_without_context():
    import jax.numpy as jnp
    x = jnp.ones((4, 8, 16))
    assert sh.shard_residual(x) is x
    assert sh.shard_logits(x) is x
