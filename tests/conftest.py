import os
import sys

# tests must see the real host device count (the 512-device override is
# exclusively for launch/dryrun.py)
assert "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", "")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
