"""End-to-end behaviour tests: the paper's full pipeline on one loop.

screen -> plan -> rewrite -> exact outputs -> kernel path agreement —
the complete "analysis and screening" + "prefetcher generation" flow of
§4, plus train/serve round trips through the public API.
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
import repro.kernels as K
import repro.models as models
from repro.configs import get_arch, reduced
from repro.serving import greedy_generate


def test_full_paper_pipeline_end_to_end():
    """Listing-1 workload: screen certifies, planner picks k, rewrite is
    bit-exact, and the Pallas kernel path agrees with the oracle."""
    N = 1 << 16
    rng = np.random.default_rng(0)
    table = rng.standard_normal((N, 8)).astype(np.float32)
    keys = rng.integers(0, 1 << 30, size=500).astype(np.int32)

    def body(carry, key):
        i, acc = carry
        idx = (key * 40503) % N
        row = jnp.take(table, idx, axis=0)
        return (i + 1, acc + row.sum()), None

    init = (jnp.int32(0), jnp.float32(0))

    # 1. screen (§4.1)
    rep = core.screen_loop(body, init, keys[0], delinquent_bytes=1 << 20)
    assert rep.critical_targets and rep.critical_targets[0].prefetchable

    # 2. plan k (§4.2 static prefetch distance)
    k = core.plan_prefetch_distance(row_bytes=32, flops_per_iter=16,
                                    hbm_bytes_per_iter=4)
    assert k >= 2

    # 3. carrot-and-horse rewrite, bit-exact (§4.2 correctness check)
    ref, _ = jax.lax.scan(body, init, keys)
    opt, _ = core.prefetch_scan(body, init, keys, prefetch_distance=k,
                                delinquent_bytes=1 << 20)
    np.testing.assert_array_equal(np.asarray(ref[1]), np.asarray(opt[1]))

    # 4. Pallas inline-prefetch kernel agrees with the jnp oracle
    idx = ((keys.astype(np.int64) * 40503) % N).astype(np.int32)
    out = K.prefetch_gather(table, jnp.asarray(idx), block_rows=8,
                            lookahead=int(min(k, 64)))
    np.testing.assert_array_equal(np.asarray(out), table[idx])


def test_train_then_serve_roundtrip(tmp_path):
    """Train a tiny model a few steps, checkpoint, restore, serve."""
    from repro.checkpoint import restore, save
    from repro.launch.mesh import make_local_mesh
    from repro.optim import AdamWConfig
    from repro.runtime import TrainConfig, Trainer

    cfg = reduced(get_arch("qwen3-8b"), n_layers=2, d_model=32, n_heads=2,
                  n_kv_heads=2, d_ff=64, vocab=128)
    tr = Trainer(cfg, TrainConfig(microbatches=1, grad_compression=False,
                                  peak_lr=3e-3, warmup=2,
                                  adamw=AdamWConfig(lr=3e-3)),
                 make_local_mesh(), seq_len=16, global_batch=4,
                 ckpt_dir=str(tmp_path))
    hist = tr.run(8, log_every=1)
    assert hist[-1][1] < hist[0][1] + 1.0          # training is sane
    tr.save()
    tr.ckpt.wait()

    restored = restore(str(tmp_path), tr.step, tr.params)
    prompts = jax.random.randint(jax.random.PRNGKey(0), (2, 8), 0,
                                 cfg.vocab_size)
    toks_a = np.asarray(greedy_generate(cfg, tr.params, prompts, 4))
    toks_b = np.asarray(greedy_generate(cfg, restored, prompts, 4))
    np.testing.assert_array_equal(toks_a, toks_b)
