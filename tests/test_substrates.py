"""Data pipeline, optimizer, compression, checkpointing, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.data import SyntheticLM
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_grads, init_error_feedback, wsd_schedule)
from repro.runtime import ElasticController, StragglerWatchdog


class TestData:
    def test_deterministic(self):
        p = SyntheticLM(1000, 16, 8, seed=3)
        a, b = p.batch(7), p.batch(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        p = SyntheticLM(1000, 16, 8, seed=3)
        assert not np.array_equal(p.batch(0)["tokens"],
                                  p.batch(1)["tokens"])

    def test_labels_are_shifted_tokens(self):
        p = SyntheticLM(1000, 16, 8)
        b = p.batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_sharding_partitions(self):
        full = SyntheticLM(1000, 8, 8, seed=1)
        parts = [SyntheticLM(1000, 8, 8, seed=1, n_hosts=4, host_id=h)
                 for h in range(4)]
        assert all(p.host_batch == 2 for p in parts)
        tok = np.concatenate([p.batch(5)["tokens"] for p in parts])
        assert tok.shape == full.batch(5)["tokens"].shape

    def test_zipf_skew(self):
        p = SyntheticLM(10000, 256, 16)
        t = np.asarray(p.batch(0)["tokens"]).ravel()
        assert (t < 100).mean() > 0.25       # heavy head

    def test_resume_state(self):
        p = SyntheticLM(50, 4, 2, seed=9)
        st = p.state(17)
        q, step = SyntheticLM.from_state(st, vocab_size=50, seq_len=4,
                                         global_batch=2)
        assert step == 17
        np.testing.assert_array_equal(p.batch(17)["tokens"],
                                      q.batch(17)["tokens"])


class TestOptim:
    def test_adamw_decreases_quadratic(self):
        params = {"w": jnp.array([3.0, -2.0])}
        opt = adamw_init(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, opt, _ = adamw_update(grads, opt, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.2

    def test_clipping_bounds_update(self):
        params = {"w": jnp.zeros(3)}
        opt = adamw_init(params)
        cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
        _, _, gnorm = adamw_update({"w": jnp.full(3, 1e6)}, opt, params, cfg)
        assert float(gnorm) > 1e5            # raw norm reported

    def test_wsd_schedule_phases(self):
        assert float(wsd_schedule(jnp.int32(0), peak_lr=1.0, warmup=10,
                                  stable=10, decay=10)) == 0.0
        assert float(wsd_schedule(jnp.int32(10), peak_lr=1.0, warmup=10,
                                  stable=10, decay=10)) == 1.0
        assert float(wsd_schedule(jnp.int32(30), peak_lr=1.0, warmup=10,
                                  stable=10, decay=10)) == pytest.approx(0.1)

    def test_error_feedback_preserves_signal(self):
        """Sum of transmitted grads + final residual == sum of true grads."""
        params = {"w": jnp.zeros(64)}
        resid = init_error_feedback(params)
        rng = np.random.default_rng(0)
        total_true, total_sent = np.zeros(64), np.zeros(64)
        for _ in range(50):
            g = {"w": jnp.asarray(rng.standard_normal(64) * 1e-3,
                                  jnp.float32)}
            sent, resid = compress_grads(g, resid)
            total_true += np.asarray(g["w"])
            total_sent += np.asarray(sent["w"])
        drift = np.abs(total_true - (total_sent + np.asarray(resid["w"])))
        assert drift.max() < 1e-5

    def test_compression_off_is_identity(self):
        g = {"w": jnp.arange(4, dtype=jnp.float32)}
        resid = init_error_feedback(g)
        out, r2 = compress_grads(g, resid, enabled=False)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(g["w"]))


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": [jnp.float32(1.5), jnp.zeros((4,), jnp.bfloat16)]}
        save(str(tmp_path), 3, tree)
        assert latest_step(str(tmp_path)) == 3
        out = restore(str(tmp_path), 3, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert out["b"][1].dtype == jnp.bfloat16

    def test_atomic_tmp_ignored(self, tmp_path):
        save(str(tmp_path), 1, {"x": jnp.ones(2)})
        os.makedirs(tmp_path / ".tmp-step_00000002")   # simulated crash
        os.makedirs(tmp_path / "step_00000005")        # no manifest
        assert latest_step(str(tmp_path)) == 1

    def test_manager_retention_and_async(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            m.save_async(s, {"x": jnp.full(4, s)})
        m.wait()
        m._gc()
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                       if n.startswith("step_"))
        assert steps == [3, 4]

    def test_shape_mismatch_rejected(self, tmp_path):
        save(str(tmp_path), 1, {"x": jnp.ones((2, 2))})
        with pytest.raises(ValueError, match="shape mismatch"):
            restore(str(tmp_path), 1, {"x": jnp.ones((3, 3))})


class TestFaultTolerance:
    def test_straggler_flagged(self):
        w = StragglerWatchdog(threshold=2.0)
        for step in range(5):
            for h in range(4):
                w.observe(f"h{h}", 1.0)
            w.observe("h_slow", 5.0)
        assert w.stragglers() == ["h_slow"]
        assert not w.healthy()

    def test_no_false_positives(self):
        w = StragglerWatchdog(threshold=2.0)
        for h in range(8):
            w.observe(f"h{h}", 1.0 + 0.01 * h)
        assert w.healthy()

    def test_elastic_mesh_proposal(self):
        ec = ElasticController(model_axis=16)
        assert ec.propose_mesh(512) == (32, 16)
        assert ec.propose_mesh(496) == (31, 16)   # lost one host of 16
        with pytest.raises(RuntimeError):
            ec.propose_mesh(8)

    def test_elastic_batch_rescale(self):
        ec = ElasticController(model_axis=16)
        assert ec.batch_for(256, 32) == 256
        assert ec.batch_for(256, 31) == 248       # per-replica batch kept
