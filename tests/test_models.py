"""Per-arch smoke tests (reduced configs): forward/train/decode on CPU,
shape + finiteness assertions.  Full configs only ever lower via dryrun."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as models
from repro.configs import ARCHS, SHAPES, cell_supported, get_arch, reduced

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((B, cfg.n_patches, cfg.d_model),
                                    jnp.float32) * 0.01
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model),
                                   jnp.float32) * 0.01
    return batch


@pytest.fixture(scope="module")
def zoo():
    return {name: (reduced(cfg),
                   models.init_params(reduced(cfg), KEY))
            for name, cfg in ARCHS.items()}


@pytest.mark.parametrize("name", list(ARCHS))
def test_train_step_smoke(zoo, name):
    cfg, params = zoo[name]
    batch = _batch(cfg)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: models.train_loss(p, b, cfg),
                           has_aux=True))(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", list(ARCHS))
def test_decode_step_smoke(zoo, name):
    cfg, params = zoo[name]
    cache = models.init_cache(cfg, B, 64)
    tok = jnp.zeros((B,), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t: models.decode_step(p, c, t, cfg))(params, cache, tok)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("name", [n for n, c in ARCHS.items()
                                  if c.family not in ("encdec",)])
def test_prefill_matches_decode(zoo, name):
    """Prefill-then-decode equals one long forward (KV-cache correctness)."""
    cfg, params = zoo[name]
    batch = _batch(cfg)
    toks = batch["tokens"]
    extra = {k: v for k, v in batch.items()
             if k not in ("tokens", "labels")}
    # full forward over S tokens
    logits_full, _, _ = models.transformer.forward(
        params, {**extra, "tokens": toks}, cfg)
    # prefill S-1 then decode token S-1 (capacity covers VLM patch prefix)
    cap = S + 1 + (cfg.n_patches if cfg.family == "vlm" else 0)
    logits_pre, cache = models.prefill(
        params, {**extra, "tokens": toks[:, :-1]}, cfg, capacity=cap)
    logits_dec, _ = models.decode_step(params, cache, toks[:, -1], cfg)
    offset = cfg.n_patches if cfg.family == "vlm" else 0
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full[:, offset + S - 1], np.float32),
        rtol=2e-2, atol=2e-2)


def test_sliding_window_ring_equivalence():
    """Ring-buffer SWA decode == linear-cache SWA decode past the window."""
    cfg = reduced(get_arch("h2o-danube-3-4b"))
    assert cfg.sliding_window == 16
    params = models.init_params(cfg, KEY)
    n = 24                       # > window
    toks = jax.random.randint(KEY, (B, n), 0, cfg.vocab_size)
    # linear reference: full forward, last-token logits
    logits_full, _, _ = models.transformer.forward(
        params, {"tokens": toks}, cfg)
    # ring decode: feed tokens one by one through a W-sized ring cache
    cache = models.init_cache(cfg, B, cfg.sliding_window)
    logits = None
    for i in range(n):
        logits, cache = models.decode_step(params, cache, toks[:, i], cfg,
                                           pos=jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(logits_full[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_long_500k_cell_support_rules():
    eligible = {n for n, c in ARCHS.items()
                if cell_supported(c, SHAPES["long_500k"])[0]}
    assert eligible == {"rwkv6-1.6b", "recurrentgemma-2b",
                        "h2o-danube-3-4b"}


def test_moe_capacity_drop_keeps_shapes():
    cfg = reduced(get_arch("deepseek-moe-16b"))
    params = models.init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, _, aux = models.transformer.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert float(aux) > 0          # load-balance loss active


def test_pallas_prefetch_paths_match_xla():
    """cfg.use_pallas_prefetch routes the embedding + MoE-dispatch
    gathers through the inline-prefetch kernel; outputs must match the
    XLA gather path (the paper's exactness requirement, end to end)."""
    import dataclasses
    cfg = reduced(get_arch("deepseek-moe-16b"))
    cfg_p = dataclasses.replace(cfg, use_pallas_prefetch=True)
    params = models.init_params(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0,
                                          cfg.vocab_size)}
    a, _, _ = models.transformer.forward(params, batch, cfg)
    b, _, _ = models.transformer.forward(params, batch, cfg_p)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_int8_kv_cache_decode_close():
    """kv_quant decode: greedy-identical on a smoke model."""
    import dataclasses
    cfg = reduced(get_arch("qwen3-8b"), n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=256)
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    params = models.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)

    def run(c):
        cache = models.init_cache(c, 2, 24)
        logits = None
        for i in range(16):
            logits, cache = models.decode_step(params, cache, toks[:, i],
                                               c, pos=jnp.int32(i))
        return np.asarray(logits, np.float32)

    a, b = run(cfg), run(cfg_q)
    assert (a.argmax(-1) == b.argmax(-1)).all()


def test_flash_triangle_model_equivalence():
    """flash_triangle is a pure schedule change: logits identical."""
    import dataclasses
    cfg = reduced(get_arch("qwen3-8b"))
    cfg_t = dataclasses.replace(cfg, flash_triangle=True)
    params = models.init_params(cfg, KEY)
    batch = _batch(cfg)
    a, _, _ = models.transformer.forward(params, batch, cfg)
    b, _, _ = models.transformer.forward(params, batch, cfg_t)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_param_count_close_to_published():
    published = {"qwen3-8b": 8.2e9, "phi4-mini-3.8b": 3.8e9,
                 "command-r-plus-104b": 104e9, "dbrx-132b": 132e9,
                 "deepseek-moe-16b": 16.4e9}
    for name, target in published.items():
        n = get_arch(name).param_count()
        assert abs(n - target) / target < 0.07, (name, n)
