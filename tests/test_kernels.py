"""Per-kernel oracle tests: shape/dtype sweeps + hypothesis properties.
All kernels run in interpret mode on CPU (TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.kernels as K

RNG = np.random.default_rng(0)


class TestPrefetchGather:
    @pytest.mark.parametrize("dtype", [np.float32, np.int32, np.float16])
    @pytest.mark.parametrize("R,D,n", [(64, 8, 16), (512, 128, 115),
                                       (33, 5, 7), (256, 96, 256)])
    def test_shapes_dtypes(self, dtype, R, D, n):
        table = (RNG.standard_normal((R, D)) * 10).astype(dtype)
        idx = RNG.integers(0, R, size=n).astype(np.int32)
        out = K.prefetch_gather(table, idx, block_rows=8, lookahead=4)
        np.testing.assert_array_equal(np.asarray(out), table[idx])

    @pytest.mark.parametrize("lookahead", [1, 2, 7, 64])
    def test_lookahead_sweep(self, lookahead):
        table = RNG.standard_normal((128, 16)).astype(np.float32)
        idx = RNG.integers(0, 128, size=40).astype(np.int32)
        out = K.prefetch_gather(table, idx, block_rows=4,
                                lookahead=lookahead)
        np.testing.assert_array_equal(np.asarray(out), table[idx])

    def test_oob_clamped_like_ref(self):
        table = RNG.standard_normal((32, 4)).astype(np.float32)
        idx = np.array([-5, 0, 31, 40], np.int32)
        out = K.prefetch_gather(table, idx, block_rows=4, lookahead=2)
        ref = K.prefetch_gather_ref(jnp.asarray(table), jnp.asarray(idx))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 200), st.integers(2, 100), st.integers(0, 2**31 - 1))
    def test_property_random(self, n, rows, seed):
        rng = np.random.default_rng(seed)
        table = rng.standard_normal((rows, 8)).astype(np.float32)
        idx = rng.integers(0, rows, size=n).astype(np.int32)
        out = K.prefetch_gather(table, idx, block_rows=8, lookahead=8)
        np.testing.assert_array_equal(np.asarray(out), table[idx])


class TestHashProbe:
    def _table(self, n_keys=200, n_slots=1024, window=8, seed=0):
        rng = np.random.default_rng(seed)
        keys = rng.choice(1 << 20, size=n_keys, replace=False).astype(np.int32)
        vals = rng.integers(0, 10000, size=n_keys).astype(np.int32)
        return K.build_table(keys, vals, n_slots, window), keys, vals

    def test_hits_and_misses(self):
        tab, keys, vals = self._table()
        rng = np.random.default_rng(3)
        misses = rng.integers(1 << 21, 1 << 22, size=64).astype(np.int32)
        q = np.concatenate([keys[:64], misses])
        got = K.hash_probe(jnp.asarray(tab), jnp.asarray(q), window=8,
                           block=8, lookahead=4)
        ref = K.hash_probe_ref(jnp.asarray(tab), jnp.asarray(q), window=8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    @pytest.mark.parametrize("window,block,lookahead",
                             [(4, 4, 2), (8, 8, 8), (16, 4, 3)])
    def test_param_sweep(self, window, block, lookahead):
        tab, keys, _ = self._table(window=window)
        got = K.hash_probe(jnp.asarray(tab), jnp.asarray(keys[:50]),
                           window=window, block=block, lookahead=lookahead)
        ref = K.hash_probe_ref(jnp.asarray(tab), jnp.asarray(keys[:50]),
                               window=window)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 80))
    def test_property_inserted_keys_found(self, seed, nq):
        tab, keys, vals = self._table(seed=seed)
        lut = dict(zip(keys.tolist(), vals.tolist()))
        rng = np.random.default_rng(seed)
        q = rng.choice(keys, size=nq)
        got = np.asarray(K.hash_probe(jnp.asarray(tab), jnp.asarray(q),
                                      window=8, block=8, lookahead=4))
        inserted = np.asarray(tab[:, 0][tab[:, 0] >= 0])
        for qi, (val, found) in zip(q.tolist(), got.tolist()):
            if qi in inserted:   # key survived bounded-probe insertion
                assert found == 1 and val == lut[qi]


class TestCsrGather:
    @pytest.mark.parametrize("n,M,D", [(16, 4, 8), (40, 8, 64), (7, 16, 5)])
    def test_shapes(self, n, M, D):
        feats = RNG.standard_normal((128, D)).astype(np.float32)
        nbrs = RNG.integers(-1, 128, size=(n, M)).astype(np.int32)
        got = K.csr_gather_mean(feats, nbrs, lookahead=4)
        ref = K.csr_gather_mean_ref(jnp.asarray(feats), jnp.asarray(nbrs))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)

    def test_all_padding_row(self):
        feats = RNG.standard_normal((32, 8)).astype(np.float32)
        nbrs = np.full((4, 4), -1, np.int32)
        got = K.csr_gather_mean(feats, nbrs, lookahead=2)
        np.testing.assert_array_equal(np.asarray(got), np.zeros((4, 8)))

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_property_random_graphs(self, seed):
        rng = np.random.default_rng(seed)
        n, M, D = rng.integers(1, 30), int(rng.integers(1, 10)), 16
        feats = rng.standard_normal((64, D)).astype(np.float32)
        nbrs = rng.integers(-1, 64, size=(n, M)).astype(np.int32)
        got = K.csr_gather_mean(feats, nbrs, lookahead=3)
        ref = K.csr_gather_mean_ref(jnp.asarray(feats), jnp.asarray(nbrs))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)


class TestPagedKV:
    @pytest.mark.parametrize("B,NP,P,page,D",
                             [(2, 3, 16, 8, 32), (4, 5, 64, 16, 32),
                              (1, 1, 4, 4, 8)])
    def test_shapes(self, B, NP, P, page, D):
        pool = RNG.standard_normal((P, page, D)).astype(np.float32)
        ptab = RNG.integers(0, P, size=(B, NP)).astype(np.int32)
        q = RNG.standard_normal((B, D)).astype(np.float32)
        got = K.paged_attn_scores(pool, ptab, q, lookahead=3)
        ref = K.paged_attn_scores_ref(jnp.asarray(pool), jnp.asarray(ptab),
                                      jnp.asarray(q))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_property(self, seed):
        rng = np.random.default_rng(seed)
        B, NP, P = (int(rng.integers(1, 5)), int(rng.integers(1, 6)),
                    int(rng.integers(1, 32)))
        pool = rng.standard_normal((P, 8, 16)).astype(np.float32)
        ptab = rng.integers(0, P, size=(B, NP)).astype(np.int32)
        q = rng.standard_normal((B, 16)).astype(np.float32)
        got = K.paged_attn_scores(pool, ptab, q, lookahead=4)
        ref = K.paged_attn_scores_ref(jnp.asarray(pool), jnp.asarray(ptab),
                                      jnp.asarray(q))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
