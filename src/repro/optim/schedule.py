"""Warmup-stable-decay LR schedule (jit-safe)."""
import jax.numpy as jnp


def wsd_schedule(step, *, peak_lr=3e-4, warmup=100, stable=1000, decay=1000,
                 floor=0.1):
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * jnp.minimum(s / max(warmup, 1), 1.0)
    t = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
    dec = peak_lr * (1.0 - (1.0 - floor) * t)
    return jnp.where(s < warmup + stable, warm, dec)
