"""Gradient compression with error feedback for cross-pod reduction.

At 512+ chips the cross-pod all-reduce rides the slowest links; casting
the reduced tensor to bf16 halves that traffic.  Error feedback keeps
the quantisation noise unbiased over time: the residual between the
true f32 gradient and its bf16 transmission is carried and added to the
next step's gradient (Seide et al. / EF-SGD style).

This runs *inside* the jitted train step (pure function of the gradient
and residual trees), so XLA sees smaller all-reduce operands — the
effect shows up directly in the roofline collective term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, residual, enabled: bool = True):
    """Returns (compressed-and-decompressed grads, new residual)."""
    if not enabled:
        return grads, residual

    def comp(g, r):
        g32 = g.astype(jnp.float32) + r
        sent = g32.astype(jnp.bfloat16)          # what crosses the pod link
        new_r = g32 - sent.astype(jnp.float32)   # error feedback
        return sent.astype(jnp.float32), new_r

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [comp(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(tree, [o[0] for o in out]),
            jax.tree.unflatten(tree, [o[1] for o in out]))
