"""AdamW with global-norm clipping.  Moments are stored f32 and inherit
the 2-D (fully-sharded) parameter shardings — ZeRO-style by construction.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig, lr=None):
    lr = cfg.lr if lr is None else lr
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m, v

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(tree, [o[0] for o in out])
    new_m = jax.tree.unflatten(tree, [o[1] for o in out])
    new_v = jax.tree.unflatten(tree, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
