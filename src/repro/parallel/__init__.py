from .sharding import (batch_pspec, data_axes_of, param_pspecs,  # noqa: F401
                       cache_pspecs, make_shardings, constrain,
                       activation_sharding, shard_residual, shard_logits,
                       gather_weights)
