"""Logical sharding rules for the production mesh.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  The batch shards over every non-"model" axis; weights are
**2-D sharded** (tensor-parallel dim on "model", the other dim on the
data axes — fully-sharded weights, ZeRO-3-style) so 104B/132B-class
models fit per-device HBM for both train and serve lowering.  XLA SPMD
inserts the all-gathers; the roofline collective term prices them.

Rules are matched on parameter-path names, with a divisibility fallback
that progressively un-shards dims that do not divide the mesh (e.g.
whisper's vocab 51866 on a 16-way "model" axis).
"""
from __future__ import annotations

import jax
import jax.tree_util as jtu
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes_of(mesh: Mesh):
    axes = tuple(a for a in mesh.axis_names if a not in ("model",))
    return axes if len(axes) > 1 else axes[0]


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fit(mesh: Mesh, shape, spec: P) -> P:
    """Drop sharding on any dim the shape does not divide."""
    fixed = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * len(shape)):
        if axis is not None and dim % _axis_size(mesh, axis) == 0:
            fixed.append(axis)
        else:
            fixed.append(None)
    return P(*fixed)


_LAST = object()


def _rule_for(path: str, ndim: int, data):
    """Return the logical PartitionSpec for a parameter path."""
    # ---- embeddings / heads ------------------------------------------
    if path.endswith("embed/table"):
        return P("model", data)
    if "lm_head" in path:
        return P(data, "model")
    # ---- attention ----------------------------------------------------
    if any(k in path for k in ("wq/w", "wk/w", "wv/w")):
        return P(data, "model")
    if "wo/w" in path:
        return P("model", data)
    # ---- MoE ----------------------------------------------------------
    if "experts/" in path:
        # (E, d, de) / (E, de, d): expert-parallel on "model"
        return P("model", data, None)
    if "shared/" in path:
        # shared banks are few (deepseek: 2) — shard the matmul dims
        return P(None, data, "model")
    if "router" in path:
        return P(data, None)
    # ---- dense MLP -----------------------------------------------------
    if any(k in path for k in ("w_gate/w", "w_up/w")):
        return P(data, "model")
    if "w_down/w" in path:
        return P("model", data)
    # ---- rwkv / rglru ---------------------------------------------------
    if any(k in path for k in ("w_r/w", "w_k/w", "w_v/w", "w_g/w",
                               "w_x/w", "w_a/w", "w_i/w")):
        return P(data, "model")
    if any(k in path for k in ("w_o/w", "w_out/w")):
        return P("model", data)
    if "decay_a" in path:
        return P(data, None)
    if "decay_b" in path:
        return P(None, "model")
    if "conv" in path:
        return P(None, "model")
    # ---- defaults: replicate scales/biases/norms -------------------------
    return P()


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jtu.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jtu.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspecs(params_tree, mesh: Mesh, mode: str = "train",
                 serve_budget_bytes: int = 11 * 2**30):
    """PartitionSpec tree for a parameter pytree (works on avals too).

    Stacked-layer parameters (the scanned units) carry a leading
    ``n_units`` axis; the name rule describes the *trailing* dims, so the
    spec is left-padded with ``None`` to the leaf's rank.

    ``mode="serve"``: weights shard on "model" ONLY (replicated over the
    data axes) when the TP-sharded copy fits ``serve_budget_bytes`` per
    chip.  Inference has no optimizer state, so the 2-D (ZeRO-style)
    sharding that training needs would force a full weight all-gather
    per decode step — the dominant collective in every baseline decode
    cell (§Perf).  Over-budget models (command-r/dbrx class) keep 2-D.
    """
    data = data_axes_of(mesh)
    drop_data = False
    if mode == "serve":
        total = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jtu.tree_leaves(params_tree))
        drop_data = total / mesh.shape["model"] <= serve_budget_bytes

    def leaf_spec(path, leaf):
        shape = leaf.shape
        spec = _rule_for(_path_str(path), len(shape), data)
        if drop_data:
            spec = P(*[None if a == data else a for a in tuple(spec)])
        pad = len(shape) - len(tuple(spec))
        if pad > 0:
            spec = P(*((None,) * pad + tuple(spec)))
        return _fit(mesh, shape, spec)

    return jtu.tree_map_with_path(leaf_spec, params_tree)


def batch_pspec(batch_tree, mesh: Mesh):
    """Shard dim 0 (batch) of every input over the data axes."""
    data = data_axes_of(mesh)

    def leaf_spec(leaf):
        if leaf.ndim == 0:
            return P()
        return _fit(mesh, leaf.shape, P(data))

    return jtu.tree_map(leaf_spec, batch_tree)


def cache_pspecs(cache_tree, mesh: Mesh):
    """KV caches / recurrent states: batch over data axes AND a model-axis
    shard on the largest remaining dim.

    GQA KV *heads* rarely divide a 16-way model axis (kv=8), so the KV
    cache shards its **sequence** dim on "model" instead — decode
    attention then computes per-shard partial softmax stats that XLA
    all-reduces (tiny: O(B·H) scalars), which is what keeps a 32k-token
    cache at ~2 GB/device instead of 38 GB replicated.  Recurrent states
    shard heads (rwkv) / channels (rglru) on "model".
    """
    data = data_axes_of(mesh)

    def leaf_spec(path, x):
        p = _path_str(path)
        nd = getattr(x, "ndim", 0)
        if nd == 0:
            return P()
        # stacked (scanned) layer caches carry a leading n_units axis
        lead = 1 if (p.startswith("units/") or p.split("/")[0] == "kv") \
            else 0
        body = nd - lead
        if "kv/" in p and body == 4:            # (B, S, Hkv, dh)
            spec = (data, "model", None, None)
        elif "rwkv/0" in p and body == 4:       # (B, H, dh, dh)
            spec = (data, "model", None, None)
        elif "rwkv/1" in p and body == 2:       # (B, d) token-shift
            spec = (data, "model")
        elif "rglru/0" in p and body == 2:      # (B, dr)
            spec = (data, "model")
        elif "rglru/1" in p and body == 3:      # (B, W-1, dr)
            spec = (data, None, "model")
        elif "enc_out" in p and body == 3:      # (B, F, d)
            spec = (data, None, "model")
        else:
            spec = (data,) + (None,) * (body - 1)
        return _fit(mesh, x.shape, P(*((None,) * lead + spec)))

    return jtu.tree_map_with_path(leaf_spec, cache_tree)


def make_shardings(pspec_tree, mesh: Mesh):
    return jtu.tree_map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(tree, pspec_tree):
    return jtu.tree_map(
        lambda spec, x: jax.lax.with_sharding_constraint(x, spec),
        pspec_tree, tree)


# ---------------------------------------------------------------------------
# Activation-sharding context.
#
# Residual activations (B, S, d) saved by per-block remat would otherwise
# be replicated over "model" (the block output all-reduce leaves them
# replicated) — at (16, 4096, 12288)·bf16 × 64 layers that alone blows
# per-device HBM.  Under this context the model constrains block
# boundaries / embeddings to shard d on "model" (sequence-parallel-style)
# and the LM logits to shard the vocab on "model" (a 40 GB/device f32
# logits tensor otherwise).  Models call the hooks unconditionally; with
# no context active they are no-ops, so single-device tests never see
# sharding machinery.
# ---------------------------------------------------------------------------

import threading

_ACT = threading.local()


class activation_sharding:
    """Context manager enabling activation constraints during tracing."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self.data = data_axes_of(mesh)

    def __enter__(self):
        _ACT.mesh, _ACT.data = self.mesh, self.data
        return self

    def __exit__(self, *exc):
        _ACT.mesh = _ACT.data = None


def _maybe(x, spec):
    mesh = getattr(_ACT, "mesh", None)
    if mesh is None or spec is None:
        return x
    fitted = _fit(mesh, x.shape, spec)
    return jax.lax.with_sharding_constraint(x, fitted)


def shard_residual(x):
    """(B, S, d): shard batch on data axes and d on "model" (SP-style)."""
    data = getattr(_ACT, "data", None)
    if data is None:
        return x
    spec = P(data, None, "model") if x.ndim == 3 else P(data, "model")
    return _maybe(x, spec)


def shard_logits(x):
    """(B, S, V) / (B, V): shard the vocab dim on "model"."""
    data = getattr(_ACT, "data", None)
    if data is None:
        return x
    spec = P(data, None, "model") if x.ndim == 3 else P(data, "model")
    return _maybe(x, spec)


def gather_weights(params_tree):
    """Re-shard weights to model-axis-only INSIDE the train step (§Perf).

    2-D (ZeRO-style) storage all-gathers every weight on every *use* —
    3 uses × microbatches per step.  Constraining params to model-only
    once, before the microbatch scan, makes the gathered copy a
    scan-invariant: XLA gathers it once per step (and reduce-scatters
    the gradient once at the boundary).  Costs 2·N/model bytes of live
    HBM — only viable when that fits (16B-class models; command-r/dbrx
    keep per-use gathering).  No-op without an activation_sharding
    context (single-device tests).
    """
    mesh = getattr(_ACT, "mesh", None)
    data = getattr(_ACT, "data", None)
    if mesh is None:
        return params_tree

    def leaf_spec(path, leaf):
        spec = _rule_for(_path_str(path), leaf.ndim, data)
        spec = P(*[None if a == data else a for a in tuple(spec)])
        pad = leaf.ndim - len(tuple(spec))
        if pad > 0:
            spec = P(*((None,) * pad + tuple(spec)))
        return _fit(mesh, leaf.shape, spec)

    return jtu.tree_map_with_path(
        lambda p, x: jax.lax.with_sharding_constraint(
            x, leaf_spec(p, x)), params_tree)
