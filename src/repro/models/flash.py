"""Flash attention in pure JAX with a custom VJP.

Tiled online-softmax forward (q × kv blocks) and a recomputing backward
— only ``(q, k, v, out, L)`` are saved, so per-device attention memory
is O(S·d) instead of O(S²) in both passes.  This is what lets the 32k
prefill and 4k train shapes of every assigned arch fit v5e HBM on the
production mesh; it is deliberately pure JAX (XLA-partitionable across
the 512-chip mesh) — the paper's contribution is the *prefetch* path,
so attention stays at the framework layer rather than a Pallas kernel.

GQA is computed in grouped form (B, Hkv, G, ...) — the KV repeat is
never materialised.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _mask(q_idx, k_idx, causal, window, skv):
    m = jnp.zeros((q_idx.shape[0], k_idx.shape[0]), jnp.float32)
    if causal:
        m = jnp.where(q_idx[:, None] >= k_idx[None, :], m, NEG_INF)
    if window is not None:
        m = jnp.where(q_idx[:, None] - k_idx[None, :] < window, m, NEG_INF)
    return jnp.where(k_idx[None, :] < skv, m, NEG_INF)


def _logits(qg, kblk, softcap):
    s = jnp.einsum("bhgsd,bhtd->bhgst", qg, kblk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    return s


def _dlogits(qg, kblk, ds, softcap):
    """Backprop ds through the optional softcap to the raw qk product."""
    if softcap is None:
        return ds
    z = jnp.einsum("bhgsd,bhtd->bhgst", qg, kblk)
    t = jnp.tanh(z / softcap)
    return ds * (1.0 - t * t)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal=True, window=None, softcap=None,
                    block=512, q_block=512, triangle=False):
    """q: (B, Sq, Hq, dh); k, v: (B, Skv, Hkv, dh) -> (B, Sq, Hq, dh).

    Scaling (1/sqrt(dh)) is applied internally.  ``triangle=True`` (§Perf
    lever) skips fully-masked causal tiles in the FORWARD pass by
    iterating only the lower-triangular (q, kv) block pairs — halving
    forward attention FLOPs at long context.  The backward pass is
    unchanged (full tiles, masked), so gradients are identical.
    """
    out, _ = _fwd(q, k, v, causal, window, softcap, block, q_block,
                  triangle)
    return out


def _shape(q, k, block, q_block):
    B, Sq, Hq, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    qb = min(q_block, Sq)
    kb = min(block, Skv)
    nq = -(-Sq // qb)
    nk = -(-Skv // kb)
    return B, Sq, Hq, dh, Skv, Hkv, qb, kb, nq, nk


def _grouped(q, k_like, Hkv):
    B, S, Hq, dh = q.shape
    return q.reshape(B, S, Hkv, Hq // Hkv, dh).transpose(0, 2, 3, 1, 4)


def _ungroup(x):                       # (B, Hkv, G, S, dh) -> (B, S, Hq, dh)
    B, Hkv, G, S, dh = x.shape
    return x.transpose(0, 3, 1, 2, 4).reshape(B, S, Hkv * G, dh)


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def _fwd(q, k, v, causal, window, softcap, block, q_block,
         triangle=False):
    B, Sq, Hq, dh, Skv, Hkv, qb, kb, nq, nk = _shape(q, k, block, q_block)
    scale = 1.0 / math.sqrt(dh)
    dtype_in = q.dtype
    qg = _grouped(q.astype(jnp.float32) * scale, k, Hkv)      # B,Hkv,G,Sq,dh
    qg = _pad_to(qg, nq * qb, axis=3)
    kf = _pad_to(k.astype(jnp.float32), nk * kb, 1)           # B,Skv,Hkv,dh
    vf = _pad_to(v.astype(jnp.float32), nk * kb, 1)
    kblocks = kf.reshape(B, nk, kb, Hkv, dh).transpose(1, 0, 3, 2, 4)
    vblocks = vf.reshape(B, nk, kb, Hkv, dh).transpose(1, 0, 3, 2, 4)
    G = Hq // Hkv

    if (triangle and causal and window is None and qb == kb
            and Sq == Skv and nq > 1):
        return _fwd_triangle(qg, kblocks, vblocks, B, Hkv, G, dh, qb, kb,
                             nq, Sq, Skv, softcap, dtype_in)

    def q_step(qi_off):
        qi, off = qi_off                                       # B,Hkv,G,qb,dh
        q_idx = off + jnp.arange(qb, dtype=jnp.int32)

        def kv_step(carry, blk):
            m_run, l_run, acc = carry
            kblk, vblk, bi = blk
            k_idx = bi * kb + jnp.arange(kb, dtype=jnp.int32)
            s = _logits(qi, kblk, softcap)
            s = s + _mask(q_idx, k_idx, causal, window, Skv)[None, None,
                                                            None]
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgst,bhtd->bhgsd", p, vblk)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Hkv, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qb, dh), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (kblocks, vblocks, jnp.arange(nk, dtype=jnp.int32)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        L = m + jnp.log(jnp.maximum(l, 1e-30))                 # logsumexp
        return out, L

    qi = qg.reshape(B, Hkv, G, nq, qb, dh).transpose(3, 0, 1, 2, 4, 5)
    offs = qb * jnp.arange(nq, dtype=jnp.int32)
    outs, Ls = lax.map(q_step, (qi, offs))        # nq,B,Hkv,G,qb,(dh)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv, G, nq * qb, dh)
    L = Ls.transpose(1, 2, 3, 0, 4).reshape(B, Hkv, G, nq * qb)
    out = _ungroup(out[:, :, :, :Sq])
    return out.astype(dtype_in), L[:, :, :, :Sq]


def _fwd_triangle(qg, kblocks, vblocks, B, Hkv, G, dh, qb, kb, nq, Sq,
                  Skv, softcap, dtype_in):
    """Forward over the lower-triangular (q, kv) block pairs only.

    One scan over nq·(nq+1)/2 tile pairs ordered q-major; the carry holds
    the running online-softmax state of the *current* q block plus the
    output/logsumexp buffers, reset at each q block's first kv tile and
    flushed at its diagonal tile.  Skipped upper tiles are the masked
    FLOPs the rectangular schedule wastes.
    """
    pairs = [(qi, ki) for qi in range(nq) for ki in range(qi + 1)]
    pq = jnp.asarray([p[0] for p in pairs], jnp.int32)
    pk = jnp.asarray([p[1] for p in pairs], jnp.int32)
    qblocks = qg.reshape(B, Hkv, G, nq, qb, dh).transpose(3, 0, 1, 2, 4, 5)

    def step(carry, pair):
        m, l, acc, out, Lb = carry
        qi, ki = pair
        qt = lax.dynamic_index_in_dim(qblocks, qi, 0, keepdims=False)
        kt = lax.dynamic_index_in_dim(kblocks, ki, 0, keepdims=False)
        vt = lax.dynamic_index_in_dim(vblocks, ki, 0, keepdims=False)
        reset = ki == 0
        m = jnp.where(reset, NEG_INF, m)
        l = jnp.where(reset, 0.0, l)
        acc = jnp.where(reset, 0.0, acc)
        q_idx = qi * qb + jnp.arange(qb, dtype=jnp.int32)
        k_idx = ki * kb + jnp.arange(kb, dtype=jnp.int32)
        s = _logits(qt, kt, softcap)
        s = s + _mask(q_idx, k_idx, True, None, Skv)[None, None, None]
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgst,bhtd->bhgsd",
                                                  p, vt)
        done = ki == qi                              # diagonal: flush
        o_blk = acc / jnp.maximum(l, 1e-30)[..., None]
        L_blk = m_new + jnp.log(jnp.maximum(l, 1e-30))
        cur_o = lax.dynamic_index_in_dim(out, qi, 0, keepdims=False)
        cur_L = lax.dynamic_index_in_dim(Lb, qi, 0, keepdims=False)
        out = lax.dynamic_update_index_in_dim(
            out, jnp.where(done, o_blk, cur_o), qi, 0)
        Lb = lax.dynamic_update_index_in_dim(
            Lb, jnp.where(done, L_blk, cur_L), qi, 0)
        return (m_new, l, acc, out, Lb), None

    m0 = jnp.full((B, Hkv, G, qb), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, qb), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, qb, dh), jnp.float32)
    out0 = jnp.zeros((nq, B, Hkv, G, qb, dh), jnp.float32)
    L0 = jnp.zeros((nq, B, Hkv, G, qb), jnp.float32)
    (_, _, _, out, Lb), _ = lax.scan(step, (m0, l0, a0, out0, L0),
                                     (pq, pk))
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv, G, nq * qb, dh)
    L = Lb.transpose(1, 2, 3, 0, 4).reshape(B, Hkv, G, nq * qb)
    return _ungroup(out[:, :, :, :Sq]).astype(dtype_in), L[:, :, :, :Sq]


def _fwd_vjp(q, k, v, causal, window, softcap, block, q_block, triangle):
    out, L = _fwd(q, k, v, causal, window, softcap, block, q_block,
                  triangle)
    return out, (q, k, v, out, L)


def _bwd_vjp(causal, window, softcap, block, q_block, triangle, res, dout):
    q, k, v, out, L = res
    B, Sq, Hq, dh, Skv, Hkv, qb, kb, nq, nk = _shape(q, k, block, q_block)
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(dh)

    qg = _pad_to(_grouped(q.astype(jnp.float32), k, Hkv), nq * qb, 3)
    og = _pad_to(_grouped(out.astype(jnp.float32), k, Hkv), nq * qb, 3)
    dg = _pad_to(_grouped(dout.astype(jnp.float32), k, Hkv), nq * qb, 3)
    Lp = _pad_to(L, nq * qb, 3)
    D = (og * dg).sum(-1)                                     # B,Hkv,G,Sq'
    kf = _pad_to(k.astype(jnp.float32), nk * kb, 1)
    vf = _pad_to(v.astype(jnp.float32), nk * kb, 1)
    kblocks = kf.reshape(B, nk, kb, Hkv, dh).transpose(1, 0, 3, 2, 4)
    vblocks = vf.reshape(B, nk, kb, Hkv, dh).transpose(1, 0, 3, 2, 4)

    def tile(qi, Li, Di, kblk, q_idx, k_idx):
        """Recompute the probability tile p = exp(s - L)."""
        s = _logits(qi * scale, kblk, softcap)
        s = s + _mask(q_idx, k_idx, causal, window, Skv)[None, None, None]
        return jnp.exp(s - Li[..., None]), s

    qi_all = qg.reshape(B, Hkv, G, nq, qb, dh).transpose(3, 0, 1, 2, 4, 5)
    dg_all = dg.reshape(B, Hkv, G, nq, qb, dh).transpose(3, 0, 1, 2, 4, 5)
    L_all = Lp.reshape(B, Hkv, G, nq, qb).transpose(3, 0, 1, 2, 4)
    D_all = D.reshape(B, Hkv, G, nq, qb).transpose(3, 0, 1, 2, 4)
    offs = qb * jnp.arange(nq, dtype=jnp.int32)

    def dq_block(args):
        qi, dgi, Li, Di, off = args
        q_idx = off + jnp.arange(qb, dtype=jnp.int32)

        def kv_step(dq_acc, blk):
            kblk, vblk, bi = blk
            k_idx = bi * kb + jnp.arange(kb, dtype=jnp.int32)
            p, _ = tile(qi, Li, Di, kblk, q_idx, k_idx)
            dp = jnp.einsum("bhgsd,bhtd->bhgst", dgi, vblk)
            ds = p * (dp - Di[..., None])
            ds = _dlogits(qi * scale, kblk, ds, softcap)
            dq_acc = dq_acc + scale * jnp.einsum(
                "bhgst,bhtd->bhgsd", ds, kblk)
            return dq_acc, None

        dq0 = jnp.zeros((B, Hkv, G, qb, dh), jnp.float32)
        dq_i, _ = lax.scan(kv_step, dq0,
                           (kblocks, vblocks,
                            jnp.arange(nk, dtype=jnp.int32)))
        return dq_i

    dq_blocks = lax.map(dq_block, (qi_all, dg_all, L_all, D_all, offs))
    dq = dq_blocks.transpose(1, 2, 3, 0, 4, 5).reshape(
        B, Hkv, G, nq * qb, dh)[:, :, :, :Sq]

    # ---- pass 2: dk, dv, scanning q blocks per kv block -------------------
    def dkv_block(args):
        kblk, vblk, bi = args
        k_idx = bi * kb + jnp.arange(kb, dtype=jnp.int32)

        def q_step(carry, qargs):
            dk_acc, dv_acc = carry
            qi, dgi, Li, Di, off = qargs
            q_idx = off + jnp.arange(qb, dtype=jnp.int32)
            p, _ = tile(qi, Li, Di, kblk, q_idx, k_idx)
            dv_acc = dv_acc + jnp.einsum("bhgst,bhgsd->bhtd", p, dgi)
            dp = jnp.einsum("bhgsd,bhtd->bhgst", dgi, vblk)
            ds = p * (dp - Di[..., None])
            ds = _dlogits(qi * scale, kblk, ds, softcap)
            dk_acc = dk_acc + scale * jnp.einsum(
                "bhgst,bhgsd->bhtd", ds, qi)
            return (dk_acc, dv_acc), None

        z = jnp.zeros((B, Hkv, kb, dh), jnp.float32)
        (dk_i, dv_i), _ = lax.scan(q_step, (z, z),
                                   (qi_all, dg_all, L_all, D_all, offs))
        return dk_i, dv_i

    dks, dvs = lax.map(dkv_block,
                       (kblocks, vblocks, jnp.arange(nk, dtype=jnp.int32)))
    dk = dks.transpose(1, 0, 3, 2, 4).reshape(B, nk * kb, Hkv, dh)[:, :Skv]
    dv = dvs.transpose(1, 0, 3, 2, 4).reshape(B, nk * kb, Hkv, dh)[:, :Skv]
    return (_ungroup_grad(dq, q), dk.astype(k.dtype), dv.astype(v.dtype))


def _ungroup_grad(dq_grouped, q_ref):
    return _ungroup(dq_grouped).astype(q_ref.dtype)


flash_attention.defvjp(_fwd_vjp, _bwd_vjp)
