"""Model zoo: a unified decoder-only transformer (dense/MoE/SSM/hybrid/
VLM) plus an encoder-decoder variant (Whisper).  Dispatch on cfg.family.
"""
from __future__ import annotations

from . import encdec, transformer
from .config import ModelConfig, MoEConfig, reduced  # noqa: F401


def _mod(cfg: ModelConfig):
    return encdec if cfg.family == "encdec" else transformer


def init_params(cfg: ModelConfig, key):
    return _mod(cfg).init_params(cfg, key)


def abstract_params(cfg: ModelConfig):
    return _mod(cfg).abstract_params(cfg)


def train_loss(params, batch, cfg: ModelConfig):
    return _mod(cfg).train_loss(params, batch, cfg)


def prefill(params, batch, cfg: ModelConfig, capacity=None):
    if cfg.family == "encdec":
        logits = encdec.forward(params, batch, cfg)
        return logits[:, -1], None
    return transformer.prefill(params, batch, cfg, capacity)


def init_cache(cfg: ModelConfig, batch: int, capacity: int):
    return _mod(cfg).init_cache(cfg, batch, capacity)


def decode_step(params, cache, token, cfg: ModelConfig, pos=None):
    return _mod(cfg).decode_step(params, cache, token, cfg, pos)
