"""GQA attention with RoPE, qk-norm, sliding windows, and a KV cache.

Prefill/train attention is a blocked online-softmax scan over KV blocks
(flash-attention schedule in pure JAX): the (Sq, Skv) logit matrix is
never materialised, which is what lets the 32k prefill shapes compile
within per-device HBM on the production mesh.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import dtype_of, init_linear, linear, rms_norm

NEG_INF = -1e30


def rope(x, positions, theta):
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.arange(half, dtype=jnp.float32) / half
    inv = theta ** -freqs                                 # (half,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :].astype(x.dtype)               # (..., S, 1, half)
    sin = sin[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)


def init_attn(key, cfg: ModelConfig, cross: bool = False):
    d, dh = cfg.d_model, cfg.head_dim
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    dtype = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    p = {"wq": init_linear(ks[0], d, Hq * dh, dtype, bias=cfg.attn_bias),
         "wk": init_linear(ks[1], d, Hkv * dh, dtype, bias=cfg.attn_bias),
         "wv": init_linear(ks[2], d, Hkv * dh, dtype, bias=cfg.attn_bias),
         "wo": init_linear(ks[3], Hq * dh, d, dtype, bias=cfg.attn_bias)}
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((dh,), dtype=dtype)}
        p["k_norm"] = {"scale": jnp.ones((dh,), dtype=dtype)}
    return p


def _project_qkv(p, x_q, x_kv, cfg: ModelConfig, q_pos, kv_pos):
    B, Sq, _ = x_q.shape
    Skv = x_kv.shape[1]
    dh, Hq, Hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = linear(p["wq"], x_q).reshape(B, Sq, Hq, dh)
    k = linear(p["wk"], x_kv).reshape(B, Skv, Hkv, dh)
    v = linear(p["wv"], x_kv).reshape(B, Skv, Hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    if q_pos is not None:
        q = rope(q, q_pos, cfg.rope_theta)
    if kv_pos is not None:
        k = rope(k, kv_pos, cfg.rope_theta)
    return q, k, v


def _mask_block(q_idx, k_idx, causal, window):
    """(Sq, Bk) additive mask block."""
    m = jnp.zeros((q_idx.shape[0], k_idx.shape[0]), dtype=jnp.float32)
    if causal:
        m = jnp.where(q_idx[:, None] >= k_idx[None, :], m, NEG_INF)
    if window is not None:
        m = jnp.where(q_idx[:, None] - k_idx[None, :] < window, m, NEG_INF)
    return m


def blocked_attention(q, k, v, *, causal: bool, window: int | None,
                      softcap: float | None = None,
                      q_offset=0, block: int = 512,
                      q_block: int = 512):
    """Online-softmax attention, tiled over BOTH q and kv blocks.

    q: (B, Sq, Hq, dh); k, v: (B, Skv, Hkv, dh).  GQA via head groups —
    no materialised KV repeat.  Returns (B, Sq, Hq, dh).

    The q tiling bounds the f32 logit tile to (B, H, q_block, block);
    without it a 32k prefill materialises multi-GB score tiles per KV
    step.
    """
    B, Sq, Hq, dh = q.shape
    if Sq > q_block:
        nqb = (Sq + q_block - 1) // q_block
        pad = nqb * q_block - Sq
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
        qb = qp.reshape(B, nqb, q_block, Hq, dh).swapaxes(0, 1)
        offs = q_offset + q_block * jnp.arange(nqb, dtype=jnp.int32)

        def one(args):
            qi, off = args
            return _blocked_attention_flat(
                qi, k, v, causal=causal, window=window, softcap=softcap,
                q_offset=off, block=block)

        out = lax.map(one, (qb, offs))                  # (nqb, B, qb, H, dh)
        out = out.swapaxes(0, 1).reshape(B, nqb * q_block, Hq, dh)
        return out[:, :Sq]
    return _blocked_attention_flat(q, k, v, causal=causal, window=window,
                                   softcap=softcap, q_offset=q_offset,
                                   block=block)


def _blocked_attention_flat(q, k, v, *, causal, window, softcap,
                            q_offset, block):
    B, Sq, Hq, dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(dh)
    qg = (q * scale).reshape(B, Sq, Hkv, G, dh).astype(jnp.float32)
    block = min(block, Skv)
    nb = (Skv + block - 1) // block
    pad = nb * block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nb, block, Hkv, dh)
    vb = v.reshape(B, nb, block, Hkv, dh)
    q_idx = q_offset + jnp.arange(Sq, dtype=jnp.int32)

    def step(carry, inp):
        m_run, l_run, acc = carry
        kblk, vblk, bi = inp
        k_idx = bi * block + jnp.arange(block, dtype=jnp.int32)
        logits = jnp.einsum("bshgd,bthd->bhgst", qg,
                            kblk.astype(jnp.float32))
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        mask = _mask_block(q_idx, k_idx, causal, window)
        mask = jnp.where(k_idx[None, :] < Skv, mask, NEG_INF)   # kv padding
        logits = logits + mask[None, None, None]
        m_new = jnp.maximum(m_run, logits.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        pexp = jnp.exp(logits - m_new[..., None])
        l_new = l_run * alpha + pexp.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bthd->bhgsd", pexp, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), dtype=jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, dh), dtype=jnp.float32)
    (m_f, l_f, acc), _ = lax.scan(
        step, (m0, l0, a0),
        (kb.swapaxes(0, 1), vb.swapaxes(0, 1),
         jnp.arange(nb, dtype=jnp.int32)))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, dh)
    return out.astype(q.dtype)


def attn_block(p, x, cfg: ModelConfig, *, causal=True, positions=None,
               x_kv=None, kv_positions=None, use_rope=True):
    """Full-sequence (train / prefill / encoder / cross) attention."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    x_kv = x if x_kv is None else x_kv
    if kv_positions is None:
        kv_positions = (positions if x_kv.shape[1] == S else
                        jnp.arange(x_kv.shape[1], dtype=jnp.int32)[None, :])
    q_pos = positions if use_rope else None
    kv_pos = kv_positions if use_rope else None
    q, k, v = _project_qkv(p, x, x_kv, cfg, q_pos, kv_pos)
    from .flash import flash_attention
    out = flash_attention(q, k, v, causal, cfg.sliding_window,
                          cfg.attn_logit_softcap,
                          triangle=cfg.flash_triangle)
    return linear(p["wo"], out.reshape(B, S, -1)), (k, v)


def init_kv_cache(cfg: ModelConfig, batch: int, capacity: int, dtype):
    dh, Hkv = cfg.head_dim, cfg.n_kv_heads
    if cfg.kv_quant:
        # int8 cache + per-(position, head) f32 scales: 0.53× the bf16
        # bytes — decode is cache-bandwidth-bound, so this moves the
        # memory roofline term directly (§Perf, lossy variant)
        return {"k": jnp.zeros((batch, capacity, Hkv, dh), jnp.int8),
                "v": jnp.zeros((batch, capacity, Hkv, dh), jnp.int8),
                "k_scale": jnp.zeros((batch, capacity, Hkv, 1),
                                     jnp.float32),
                "v_scale": jnp.zeros((batch, capacity, Hkv, 1),
                                     jnp.float32)}
    return {"k": jnp.zeros((batch, capacity, Hkv, dh), dtype=dtype),
            "v": jnp.zeros((batch, capacity, Hkv, dh), dtype=dtype)}


def _quant_i8(x):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def decode_attn(p, x, cache, pos, cfg: ModelConfig, *, use_rope=True):
    """One-token decode.  x: (B, 1, d); pos: () int32 — the index of the
    new token (the cache holds the KV of positions < pos).

    Two cache layouts, chosen by capacity:

    * **linear** (capacity > window, or no window): write at slot ``pos``,
      score every slot with ``k_idx <= pos`` (+ window mask if SWA);
    * **ring** (SWA and capacity == window): write at ``pos % W``; slot
      ``j`` then holds absolute position ``pos - ((pos - j) mod W)``,
      which is always inside the window, so only ``p_j >= 0`` needs
      masking.  This keeps long-context decode (long_500k) at O(W)
      memory — the TPU-side reason SWA archs are long-context-eligible.

    RoPE is applied at insert time, so ring rotation never re-rotates.
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, x, cfg,
                                   positions if use_rope else None,
                                   positions if use_rope else None)
    capacity = cache["k"].shape[1]
    W = cfg.sliding_window
    ring = W is not None and capacity == W
    slot = lax.rem(pos, capacity) if ring else pos
    quant = "k_scale" in cache
    if quant:
        kq, ks = _quant_i8(k_new)
        vq, vs = _quant_i8(v_new)
        new_cache_kv = {
            "k": lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0)),
            "v": lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0)),
            "k_scale": lax.dynamic_update_slice(cache["k_scale"], ks,
                                                (0, slot, 0, 0)),
            "v_scale": lax.dynamic_update_slice(cache["v_scale"], vs,
                                                (0, slot, 0, 0)),
        }
        k = (new_cache_kv["k"].astype(jnp.float32)
             * new_cache_kv["k_scale"])
        v = (new_cache_kv["v"].astype(jnp.float32)
             * new_cache_kv["v_scale"])
    else:
        k = lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
        v = lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
        new_cache_kv = {"k": k, "v": v}
    j = jnp.arange(capacity, dtype=jnp.int32)
    if ring:
        abs_pos = pos - lax.rem(pos - j + capacity * 2, capacity)
        valid = abs_pos >= 0
    else:
        valid = j <= pos
        if W is not None:
            valid = valid & (pos - j < W)
    dh = cfg.head_dim
    Hkv = cfg.n_kv_heads
    G = cfg.n_heads // Hkv
    qg = (q * (1.0 / math.sqrt(dh))).reshape(B, 1, Hkv, G, dh)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    if cfg.attn_logit_softcap is not None:
        logits = cfg.attn_logit_softcap * jnp.tanh(
            logits / cfg.attn_logit_softcap)
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", w, v.astype(jnp.float32))
    out = out.reshape(B, 1, -1).astype(x.dtype)
    return linear(p["wo"], out), new_cache_kv
