"""Unified decoder-only model covering the dense / MoE / SSM / hybrid /
VLM families.  Pure JAX; params are nested dicts; every entry point is
jit/pjit-compatible and lowers with ShapeDtypeStruct inputs (dry-run).

Layer stacking
--------------
Layers are organised as *units* of the mixer pattern (e.g. RecurrentGemma
= (rglru, rglru, attn)) and the repeated units are **stacked and scanned**
(``lax.scan`` over a leading ``n_units`` parameter axis, with per-unit
rematerialisation).  A 64-layer model lowers to ONE unit body in HLO
instead of 64 copies — compile time and program size drop by ~n_layers×,
which is what makes the 80-cell production dry-run tractable.  Layers
beyond the last full unit ("remainder") run as plain Python blocks.

Entry points
------------
``init_params(cfg, key)``                           real weights (smoke/tests)
``abstract_params(cfg)``                            ShapeDtypeStructs (dry-run)
``train_loss(params, batch, cfg)``                  scalar loss + metrics
``prefill(params, batch, cfg)``                     logits + cache
``decode_step(params, cache, token, cfg)``          one-token serve step
``init_cache(cfg, batch, capacity)``                cache skeleton
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import attention as A
from . import moe as M
from . import rglru as G
from . import rwkv6 as R
from .config import ModelConfig
from .layers import (dtype_of, embed, init_embedding, init_linear, init_mlp,
                     init_rms, linear, mlp, rms_norm, softmax_xent, unembed)


def layer_plan(cfg: ModelConfig):
    """(pattern, n_units, remainder_kinds)."""
    P = len(cfg.mixer_pattern)
    n_units = cfg.n_layers // P
    rem = [cfg.mixer_of(n_units * P + r) for r in range(cfg.n_layers % P)]
    return cfg.mixer_pattern, n_units, rem


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, kind: str):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"norm1": init_rms(cfg.d_model, dtype_of(cfg)),
         "norm2": init_rms(cfg.d_model, dtype_of(cfg))}
    if kind == "attn":
        p["attn"] = A.init_attn(k1, cfg)
    elif kind == "rwkv6":
        p["rwkv"] = R.init_rwkv6(k1, cfg)
    elif kind == "rglru":
        p["rglru"] = G.init_rglru(k1, cfg)
    else:
        raise ValueError(kind)
    if cfg.moe is not None:
        p["moe"] = M.init_moe(k2, cfg)
    else:
        p["mlp"] = init_mlp(k3, cfg)
    return p


def init_params(cfg: ModelConfig, key):
    pattern, n_units, rem = layer_plan(cfg)
    k_embed, k_head, k_units, k_rem = jax.random.split(key, 4)
    params = {
        "embed": init_embedding(k_embed, cfg.padded_vocab, cfg.d_model,
                                dtype_of(cfg)),
        "final_norm": init_rms(cfg.d_model, dtype_of(cfg)),
        # units[j]: params of pattern position j, stacked over n_units
        "units": [
            jax.vmap(lambda k: init_block(k, cfg, kind))(
                jax.random.split(jax.random.fold_in(k_units, j), n_units))
            for j, kind in enumerate(pattern)
        ],
        "rem": [init_block(jax.random.fold_in(k_rem, r), cfg, kind)
                for r, kind in enumerate(rem)],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(k_head, cfg.d_model,
                                        cfg.padded_vocab, dtype_of(cfg))
    return params


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _apply_block_seq(p, x, cfg: ModelConfig, kind: str, positions,
                     state=None):
    """Train/prefill path.  Returns (x, new_state, aux)."""
    h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
    if kind == "attn":
        out, kv = A.attn_block(p["attn"], h, cfg, causal=True,
                               positions=positions)
        new_state = {"kv": {"k": kv[0], "v": kv[1]}}
    elif kind == "rwkv6":
        out, s = R.rwkv6_seq(p["rwkv"], h, cfg,
                             None if state is None else state.get("rwkv"))
        new_state = {"rwkv": s}
    else:
        out, s = G.rglru_seq(p["rglru"], h, cfg,
                             None if state is None else state.get("rglru"))
        new_state = {"rglru": s}
    x = x + out
    h = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
    if cfg.moe is not None:
        out, aux = M.moe_block(p["moe"], h, cfg)
    else:
        out, aux = mlp(p["mlp"], h, cfg), jnp.float32(0)
    return x + out, new_state, aux


def _apply_block_decode(p, x, cfg: ModelConfig, kind: str, cache, pos):
    """Decode path.  x: (B, 1, d)."""
    h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
    if kind == "attn":
        out, kv = A.decode_attn(p["attn"], h, cache["kv"], pos, cfg)
        new_cache = {"kv": kv}
    elif kind == "rwkv6":
        out, s = R.rwkv6_step(p["rwkv"], h[:, 0], cache["rwkv"], cfg)
        out = out[:, None]
        new_cache = {"rwkv": s}
    else:
        out, s = G.rglru_step(p["rglru"], h[:, 0], cache["rglru"], cfg)
        out = out[:, None]
        new_cache = {"rglru": s}
    x = x + out
    h = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
    if cfg.moe is not None:
        out, _ = M.moe_block(p["moe"], h, cfg)
    else:
        out = mlp(p["mlp"], h, cfg)
    return x + out, new_cache


# ---------------------------------------------------------------------------
# input assembly (token / VLM-prefix stubs)
# ---------------------------------------------------------------------------

def _assemble_inputs(params, batch, cfg: ModelConfig):
    tok_emb = embed(params["embed"], batch["tokens"], cfg)
    labels = batch.get("labels")
    if cfg.n_patches:
        patches = batch["patches"].astype(tok_emb.dtype)   # (B, P, d) stub
        x = jnp.concatenate([patches, tok_emb], axis=1)
        if labels is not None:
            B, P = patches.shape[0], patches.shape[1]
            ignore = jnp.full((B, P), -100, dtype=labels.dtype)
            labels = jnp.concatenate([ignore, labels], axis=1)
        return x, labels
    return tok_emb, labels


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def forward(params, batch, cfg: ModelConfig, *, return_states=False):
    from ..parallel import shard_logits, shard_residual
    pattern, n_units, rem = layer_plan(cfg)
    x, labels = _assemble_inputs(params, batch, cfg)
    x = shard_residual(x)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def unit_body(carry, unit_params):
        x, aux = carry
        states = []
        for j, kind in enumerate(pattern):
            x, st, a = _apply_block_seq(unit_params[j], x, cfg, kind,
                                        positions)
            x = shard_residual(x)
            states.append(st)
            aux = aux + a
        return (x, aux), states

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(unit_body, policy=policy)
    else:
        body = unit_body
    if n_units > 0:
        (x, aux), unit_states = jax.lax.scan(
            body, (x, jnp.float32(0)), params["units"])
    else:
        aux, unit_states = jnp.float32(0), [
            None for _ in pattern]
    rem_states = []
    for r, kind in enumerate(rem):
        x, st, a = _apply_block_seq(params["rem"][r], x, cfg, kind,
                                    positions)
        x = shard_residual(x)
        rem_states.append(st)
        aux = aux + a
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = shard_logits(
        unembed(params["embed"], params.get("lm_head"), x, cfg))
    if return_states:
        return logits, labels, (unit_states, rem_states), aux
    return logits, labels, aux


def train_loss(params, batch, cfg: ModelConfig):
    logits, labels, aux = forward(params, batch, cfg)
    loss = softmax_xent(logits, labels)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux}


def prefill(params, batch, cfg: ModelConfig, capacity: int | None = None):
    """Run the prompt; return (last-token logits, cache ready for decode)."""
    logits, _, (unit_states, rem_states), _ = forward(
        params, batch, cfg, return_states=True)
    S = logits.shape[1]
    capacity = capacity or S

    def to_cache(st):
        if st is None or "kv" not in st:
            return st
        k, v = st["kv"]["k"], st["kv"]["v"]
        pad = capacity - k.shape[-3]
        if pad > 0:
            cfg_pad = [(0, 0)] * k.ndim
            cfg_pad[-3] = (0, pad)
            k, v = jnp.pad(k, cfg_pad), jnp.pad(v, cfg_pad)
        return {"kv": {"k": k, "v": v}}

    cache = {
        "units": [to_cache(st) for st in unit_states],
        "rem": [to_cache(st) for st in rem_states],
        "pos": jnp.int32(S),
    }
    return logits[:, -1], cache


def _cache_entry(cfg: ModelConfig, kind: str, batch: int, capacity: int,
                 dtype, n_units: int | None = None):
    def stack(tree):
        if n_units is None:
            return tree
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_units,) + x.shape), tree)

    if kind == "attn":
        cap = capacity
        if cfg.sliding_window is not None:
            cap = min(capacity, cfg.sliding_window)   # ring buffer
        return stack({"kv": A.init_kv_cache(cfg, batch, cap, dtype)})
    if kind == "rwkv6":
        return stack({"rwkv": R.init_rwkv_state(cfg, batch)})
    return stack({"rglru": G.init_rglru_state(cfg, batch)})


def init_cache(cfg: ModelConfig, batch: int, capacity: int):
    """Cache skeleton for a ``capacity``-token context (dry-run friendly)."""
    pattern, n_units, rem = layer_plan(cfg)
    dtype = dtype_of(cfg)
    return {
        "units": [_cache_entry(cfg, kind, batch, capacity, dtype, n_units)
                  for kind in pattern],
        "rem": [_cache_entry(cfg, kind, batch, capacity, dtype)
                for kind in rem],
        "pos": jnp.int32(0),
    }


def decode_step(params, cache, token, cfg: ModelConfig, pos=None):
    """token: (B,) int32.  Returns (logits (B, V), new cache)."""
    pattern, n_units, rem = layer_plan(cfg)
    if pos is None:
        pos = cache["pos"]
    x = embed(params["embed"], token[:, None], cfg)

    def unit_body(x, inp):
        unit_params, unit_cache = inp
        new_cache = []
        for j, kind in enumerate(pattern):
            x, nc = _apply_block_decode(unit_params[j], x, cfg, kind,
                                        unit_cache[j], pos)
            new_cache.append(nc)
        return x, new_cache

    if n_units > 0:
        x, new_units = jax.lax.scan(unit_body, x,
                                    (params["units"], cache["units"]))
    else:
        new_units = cache["units"]
    new_rem = []
    for r, kind in enumerate(rem):
        x, nc = _apply_block_decode(params["rem"][r], x, cfg, kind,
                                    cache["rem"][r], pos)
        new_rem.append(nc)
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], params.get("lm_head"), x[:, 0], cfg)
    from ..parallel import shard_logits
    return shard_logits(logits), {"units": new_units, "rem": new_rem,
                                  "pos": pos + 1}
