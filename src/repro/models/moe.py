"""Fine-grained Mixture-of-Experts with sort-based dispatch.

Dispatch is the MoE-side DIL (paper §1.1: ``a[b[i]]`` where ``b`` is the
router output): tokens are gathered into per-expert buffers through an
irregular index stream that is *runnable* — the routing decision depends
only on the router logits, not on the gathered expert weights — so the
token gather/scatter is exactly the access pattern the inline prefetcher
targets (see kernels/prefetch_gather; the distributed path below uses
XLA gather/scatter so it shards over the "model"/expert axis).

Capacity-bounded: ``C = ceil(T * top_k / E * capacity_factor)``; overflow
tokens are dropped from the routed path (standard practice), shared
experts always run densely (DeepSeek-MoE's 2 shared experts).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dtype_of, init_linear, linear


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d, dtype = cfg.d_model, dtype_of(cfg)
    de = m.d_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)

    def bank(k, n):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "w_gate": (jax.random.normal(k1, (n, d, de), jnp.float32)
                       * scale).astype(dtype),
            "w_up": (jax.random.normal(k2, (n, d, de), jnp.float32)
                     * scale).astype(dtype),
            "w_down": (jax.random.normal(k3, (n, de, d), jnp.float32)
                       * (1.0 / math.sqrt(de))).astype(dtype),
        }

    p = {"router": init_linear(ks[0], d, m.n_experts, dtype),
         "experts": bank(ks[1], m.n_experts)}
    if m.n_shared:
        p["shared"] = bank(ks[2], m.n_shared)
    return p


_FFN_CHUNK = 2048


def _expert_ffn(bank, x):
    """x: (E, C, d) -> (E, C, d) SwiGLU via per-expert weights.

    Chunked over the capacity dim: the (E, C, d_ff) hidden transient at
    dbrx scale (16 × 8192 × 10752 bf16 ≈ 5.6 GB/device, ×3 live copies)
    is what blew the 32k-prefill cell past HBM; chunks bound it to
    C=2048 slices.
    """
    E, C, d = x.shape
    if C <= _FFN_CHUNK or C % _FFN_CHUNK != 0:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, bank["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", x, bank["w_up"])
        return jnp.einsum("ecf,efd->ecd", h, bank["w_down"])
    nc = C // _FFN_CHUNK
    xc = x.reshape(E, nc, _FFN_CHUNK, d).transpose(1, 0, 2, 3)

    def one(xi):
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xi, bank["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", xi, bank["w_up"])
        return jnp.einsum("ecf,efd->ecd", h, bank["w_down"])

    out = jax.lax.map(one, xc)                       # (nc, E, chunk, d)
    return out.transpose(1, 0, 2, 3).reshape(E, C, d)


def moe_block(p, x, cfg: ModelConfig):
    """Grouped dispatch: routing/sort/scatter run independently per batch
    row (``jax.vmap`` over B).  The group axis shards over "data", the
    expert axis over "model" (EP) — without grouping, the global argsort
    and (T·K, d) gather materialise unsharded multi-GB dispatch tensors
    under SPMD (observed 53 GB/device on the 32k-prefill dry-run cell).
    Capacity is group-local: C = ceil(S·K/E · cf).
    """
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.n_experts, m.top_k
    # Big dispatches run sequentially over groups (lax.map) with the
    # sequence split into <=8k-token chunks: vmap materialises every
    # group's (S·K, d) gather/scatter tensors at once (27+ GB/device on
    # the dbrx 32k-prefill cell); map keeps one chunk live.
    seq_chunk = S
    dispatch_bytes = B * S * K * d * 2
    if dispatch_bytes > 1 << 30 and S % 8192 == 0 and S > 8192:
        seq_chunk = 8192
    C = max(1, int(math.ceil(seq_chunk * K / E * m.capacity_factor)))

    def one_chunk(xf):                                        # (Sc, d)
        S = xf.shape[0]
        logits = linear(p["router"], xf).astype(jnp.float32)  # (S, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, sel = jax.lax.top_k(probs, K)                 # (S, K)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

        # ---- sort-based dispatch (the irregular gather/scatter) ---------
        e_flat = sel.reshape(-1)                              # (S*K,)
        t_flat = jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)
        w_flat = gate_w.reshape(-1)
        order = jnp.argsort(e_flat)                           # stable
        e_s, t_s, w_s = e_flat[order], t_flat[order], w_flat[order]
        seg_sizes = jax.ops.segment_sum(jnp.ones_like(e_s), e_s,
                                        num_segments=E)
        seg_start = jnp.concatenate(
            [jnp.zeros((1,), seg_sizes.dtype), jnp.cumsum(seg_sizes)[:-1]])
        pos = (jnp.arange(S * K, dtype=jnp.int32)
               - seg_start[e_s].astype(jnp.int32))
        keep = pos < C
        pos_c = jnp.minimum(pos, C - 1)

        # gather tokens -> expert buffers (DIL #1).  Unclamped ``pos`` +
        # mode="drop": overflow tokens fall out of the scatter instead of
        # clobbering slot C-1.  The single-core serving path routes the
        # gather through the inline-prefetch Pallas kernel (the router
        # output is a runnable index stream — the paper's a[b[i]]); the
        # distributed path keeps the XLA gather (SPMD-shardable).
        if cfg.use_pallas_prefetch:
            from ..kernels import prefetch_gather
            rows = prefetch_gather(xf, t_s)
        else:
            rows = xf[t_s]
        buf = jnp.zeros((E, C, d), dtype=xf.dtype).at[e_s, pos].set(
            rows, mode="drop")

        out_buf = _expert_ffn(p["experts"], buf)              # (E, C, d)

        # combine (DIL #2: scatter-add back to token order)
        back = out_buf[e_s, pos_c] * (w_s * keep).astype(xf.dtype)[:, None]
        out = jnp.zeros((S, d), dtype=xf.dtype).at[t_s].add(
            back, mode="drop")
        return out, _load_balance_loss(probs, sel, E)

    def one_group(xf):                                        # (S, d)
        if seq_chunk != S:
            nc = S // seq_chunk
            outs, auxs = jax.lax.map(
                one_chunk, xf.reshape(nc, seq_chunk, d))
            return outs.reshape(S, d), auxs.mean()
        return one_chunk(xf)

    out, aux = jax.vmap(one_group)(x)                         # (B, S, d)

    if "shared" in p:
        sh = p["shared"]
        n_sh = sh["w_gate"].shape[0]
        xf = x.reshape(B * S, d)
        xe = jnp.broadcast_to(xf, (n_sh, B * S, d))
        out = out + _expert_ffn(sh, xe).sum(axis=0).reshape(B, S, d)

    return out, aux.mean()


def _load_balance_loss(probs, sel, E):
    """Switch-style auxiliary loss (mean prob × mean assignment)."""
    T, K = sel.shape
    hot = jax.nn.one_hot(sel, E, dtype=jnp.float32).sum(axis=1)  # (T, E)
    frac_tokens = hot.mean(axis=0)
    frac_probs = probs.mean(axis=0)
    return E * jnp.sum(frac_tokens * frac_probs) / K
