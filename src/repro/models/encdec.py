"""Encoder-decoder backbone (Whisper-large-v3 shape).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, encoder_seq, d_model).  The
encoder is bidirectional; the decoder is the standard causal transformer
plus per-layer cross-attention to the encoder output.  Assigned shapes
apply to the *decoder* token stream; the encoder length is fixed
(cfg.encoder_seq).

Both stacks are stored stacked (leading n_layers axis) and scanned —
see transformer.py for why (HLO size / compile time at 512 devices).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as A
from .config import ModelConfig
from .layers import (dtype_of, embed, init_embedding, init_linear, init_mlp,
                     init_rms, mlp, rms_norm, softmax_xent, unembed)


def _enc_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"norm1": init_rms(cfg.d_model, dtype_of(cfg)),
            "attn": A.init_attn(k1, cfg),
            "norm2": init_rms(cfg.d_model, dtype_of(cfg)),
            "mlp": init_mlp(k2, cfg)}


def _dec_block_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": init_rms(cfg.d_model, dtype_of(cfg)),
            "self_attn": A.init_attn(k1, cfg),
            "norm_x": init_rms(cfg.d_model, dtype_of(cfg)),
            "cross_attn": A.init_attn(k2, cfg),
            "norm2": init_rms(cfg.d_model, dtype_of(cfg)),
            "mlp": init_mlp(k3, cfg)}


def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    dtype = dtype_of(cfg)
    return {
        "embed": init_embedding(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg))(
            jax.random.split(ks[1], cfg.n_encoder_layers)),
        "enc_norm": init_rms(cfg.d_model, dtype),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg))(
            jax.random.split(ks[2], cfg.n_layers)),
        "final_norm": init_rms(cfg.d_model, dtype),
        "lm_head": init_linear(ks[3], cfg.d_model, cfg.padded_vocab, dtype),
    }


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def encode(params, frames, cfg: ModelConfig):
    from ..parallel import shard_residual
    x = shard_residual(frames.astype(dtype_of(cfg)))

    def block(x, p):
        h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
        out, _ = A.attn_block(p["attn"], h, cfg, causal=False)
        x = x + out
        h = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
        return shard_residual(x + mlp(p["mlp"], h, cfg)), None

    body = jax.checkpoint(block) if cfg.remat else block
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)


def _dec_block(p, x, enc_out, cfg, positions):
    h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
    out, kv = A.attn_block(p["self_attn"], h, cfg, causal=True,
                           positions=positions)
    x = x + out
    h = rms_norm(x, p["norm_x"]["scale"], cfg.norm_eps)
    out, _ = A.attn_block(p["cross_attn"], h, cfg, causal=False,
                          x_kv=enc_out, use_rope=False)
    x = x + out
    h = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
    return x + mlp(p["mlp"], h, cfg), kv


def forward(params, batch, cfg: ModelConfig, return_states=False):
    from ..parallel import shard_logits, shard_residual
    enc_out = encode(params, batch["frames"], cfg)
    x = shard_residual(embed(params["embed"], batch["tokens"], cfg))
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def block(x, p):
        x, kv = _dec_block(p, x, enc_out, cfg, positions)
        return shard_residual(x), {"kv": {"k": kv[0], "v": kv[1]}}

    body = jax.checkpoint(block) if cfg.remat else block
    x, kv_stack = jax.lax.scan(body, x, params["dec_blocks"])
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = shard_logits(
        unembed(params["embed"], params.get("lm_head"), x, cfg))
    if return_states:
        return logits, enc_out, kv_stack
    return logits


def train_loss(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    loss = softmax_xent(logits, batch["labels"])
    return loss, {"loss": loss, "aux": jnp.float32(0)}


def init_cache(cfg: ModelConfig, batch: int, capacity: int):
    dtype = dtype_of(cfg)
    kv = A.init_kv_cache(cfg, batch, capacity, dtype)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), kv)
    return {"kv": stacked,
            "enc_out": jnp.zeros((batch, cfg.encoder_seq, cfg.d_model),
                                 dtype=dtype),
            "pos": jnp.int32(0)}


def decode_step(params, cache, token, cfg: ModelConfig, pos=None):
    """One decoder token against cached self-KV + fixed encoder output."""
    if pos is None:
        pos = cache["pos"]
    x = embed(params["embed"], token[:, None], cfg)
    enc_out = cache["enc_out"]

    def block(x, inp):
        p, kv_cache = inp
        h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
        out, kv = A.decode_attn(p["self_attn"], h, kv_cache, pos, cfg)
        x = x + out
        h = rms_norm(x, p["norm_x"]["scale"], cfg.norm_eps)
        out, _ = A.attn_block(p["cross_attn"], h, cfg, causal=False,
                              x_kv=enc_out, use_rope=False)
        x = x + out
        h = rms_norm(x, p["norm2"]["scale"], cfg.norm_eps)
        return x + mlp(p["mlp"], h, cfg), kv

    x, new_kv = jax.lax.scan(block, x, (params["dec_blocks"], cache["kv"]))
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = unembed(params["embed"], params.get("lm_head"), x[:, 0], cfg)
    return logits, {"kv": new_kv, "enc_out": enc_out, "pos": pos + 1}
