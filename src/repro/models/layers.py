"""Shared layers: norms, embeddings, MLPs.  Pure JAX, params are dicts."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def init_rms(d, dtype):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def init_linear(key, d_in, d_out, dtype, bias=False):
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
    w = (w / jnp.sqrt(d_in)).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# Embedding — the first-class DIL site.  The vocab table is HBM-resident
# (hundreds of MB for 150k-256k vocabs) and the token-id stream is runnable
# (it comes from the data pipeline, independent of the gathered rows), so
# this is exactly the paper's prefetchable gather.  The distributed path
# uses jnp.take (XLA SPMD shards the table row-wise over "model"); the
# single-core serving/bench path can route through the Pallas
# prefetch_gather kernel via cfg.use_pallas_prefetch.
# ---------------------------------------------------------------------------

def init_embedding(key, vocab, d, dtype):
    w = jax.random.normal(key, (vocab, d), dtype=jnp.float32)
    return {"table": (w * 0.02).astype(dtype)}


def embed(p, tokens, cfg: ModelConfig):
    if cfg.use_pallas_prefetch:
        from ..kernels import prefetch_gather
        flat = tokens.reshape(-1)
        rows = prefetch_gather(p["table"], flat)
        return rows.reshape(tokens.shape + (p["table"].shape[1],))
    # Decode-scale lookups use a one-hot matmul: SPMD partitions the
    # contraction over the vocab-sharded table cleanly (a partial-sum
    # all-reduce of (B, d)), where the equivalent gather makes the
    # partitioner replicate the table — +6.3 GB/device at command-r's
    # 256k vocab (XLA "involuntary full rematerialization" warning).
    if tokens.size <= 8192:
        table = p["table"]
        hot = jax.nn.one_hot(tokens.reshape(-1), table.shape[0],
                             dtype=table.dtype)
        return (hot @ table).reshape(tokens.shape + (table.shape[1],))
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p_embed, p_head, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        logits = x @ p_embed["table"].T
    else:
        logits = x @ p_head["w"]
    if cfg.padded_vocab != cfg.vocab_size:   # mask vocab-padding columns
        cols = jnp.arange(cfg.padded_vocab, dtype=jnp.int32)
        logits = jnp.where(cols < cfg.vocab_size, logits,
                           jnp.asarray(-1e30, logits.dtype))
    return logits


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff=None):
    d, dtype = cfg.d_model, dtype_of(cfg)
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {"w_gate": init_linear(ks[0], d, ff, dtype),
                "w_up": init_linear(ks[1], d, ff, dtype),
                "w_down": init_linear(ks[2], ff, d, dtype)}
    return {"w_up": init_linear(ks[0], d, ff, dtype),
            "w_down": init_linear(ks[1], ff, d, dtype)}


def mlp(p, x, cfg: ModelConfig):
    if cfg.act == "swiglu":
        h = jax.nn.silu(linear(p["w_gate"], x)) * linear(p["w_up"], x)
    else:
        h = jax.nn.gelu(linear(p["w_up"], x))
    return linear(p["w_down"], h)


def softmax_xent(logits, labels, mask=None):
    """Mean token cross-entropy in f32.  labels: int32, -100 = ignore."""
    logits = logits.astype(jnp.float32)
    valid = (labels >= 0) if mask is None else mask
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid.astype(jnp.float32)
    return nll.sum() / jnp.maximum(valid.sum(), 1)
