"""RWKV-6 "Finch" time-mix layer (data-dependent decay linear attention).

Per head, state ``S`` is a (dh, dh) matrix updated per token:

    out_t = r_t · (S + (u ⊙ k_t) v_tᵀ)
    S     = diag(w_t) S + k_t v_tᵀ

with the decay ``w_t = exp(-exp(decay(x_t)))`` *data-dependent* (the
Finch contribution) and token-shift interpolation on the projections.

DIL-screen note (DESIGN.md §Arch-applicability): the state recurrence
``S_t = f(x_t) · S_{t-1} + ...`` is a loop-carried cycle whose inputs are
the live activations — under the paper's taxonomy this is *chasing*-like
(un-prefetchable, the bottleneck IS the serial chain), so the inline
prefetcher is *not* applied here; it still applies to the embedding
gather feeding this model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import dtype_of, init_linear, linear


def n_rwkv_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def init_rwkv6(key, cfg: ModelConfig):
    d, dtype = cfg.d_model, dtype_of(cfg)
    dh = cfg.rwkv_head_dim
    H = n_rwkv_heads(cfg)
    ks = jax.random.split(key, 8)
    lora = 32
    return {
        "w_r": init_linear(ks[0], d, d, dtype),
        "w_k": init_linear(ks[1], d, d, dtype),
        "w_v": init_linear(ks[2], d, d, dtype),
        "w_g": init_linear(ks[3], d, d, dtype),
        "w_o": init_linear(ks[4], d, d, dtype),
        # data-dependent decay: low-rank ddlerp (Finch)
        "decay_a": init_linear(ks[5], d, lora, dtype),
        "decay_b": init_linear(ks[6], lora, d, dtype),
        "decay_base": jnp.full((d,), -5.0, dtype=dtype),
        "bonus": jnp.zeros((H, dh), dtype=dtype),
        # token-shift mix coefficients
        "mix": jnp.full((5, d), 0.5, dtype=dtype),
    }


def _proj(p, x_cur, x_prev):
    """Token-shift interpolation then the five projections."""
    mixed = [x_cur * m + x_prev * (1 - m) for m in p["mix"]]
    r = linear(p["w_r"], mixed[0])
    k = linear(p["w_k"], mixed[1])
    v = linear(p["w_v"], mixed[2])
    g = jax.nn.silu(linear(p["w_g"], mixed[3]))
    decay = p["decay_base"] + linear(
        p["decay_b"], jnp.tanh(linear(p["decay_a"], mixed[4])))
    w = jnp.exp(-jnp.exp(decay.astype(jnp.float32)))        # (…, d) in (0,1)
    return r, k, v, g, w


def _heads(x, H, dh):
    return x.reshape(x.shape[:-1] + (H, dh))


def rwkv6_seq(p, x, cfg: ModelConfig, state=None, chunk: int = 64):
    """Full-sequence time-mix.  x: (B, S, d) -> (out, (S_state, x_last)).

    Memory discipline for long sequences (the 4k-train / 32k-prefill
    shapes): the five projections are computed as full-sequence matmuls
    *outside* the recurrence (MXU-shaped work), and the serial state
    update runs as a **chunked scan with rematerialisation** — only the
    (B, H, dh, dh) state at chunk boundaries is saved for backward, and
    each chunk's internals are recomputed during the backward pass.
    Without this, a 4096-step scan stashes ~34 GB/device of per-step
    outer products.
    """
    B, S, d = x.shape
    dh = cfg.rwkv_head_dim
    H = n_rwkv_heads(cfg)
    if state is None:
        s0 = jnp.zeros((B, H, dh, dh), dtype=jnp.float32)
        x_prev0 = jnp.zeros((B, d), dtype=x.dtype)
    else:
        s0, x_prev0 = state
    u = p["bonus"].astype(jnp.float32)

    # vectorised token shift + projections over the whole sequence
    x_shift = jnp.concatenate([x_prev0[:, None], x[:, :-1]], axis=1)
    r, k, v, g, w = _proj(p, x, x_shift)                  # each (B, S, d)
    rh = _heads(r, H, dh).astype(jnp.float32)             # (B, S, H, dh)
    kh = _heads(k, H, dh).astype(jnp.float32)
    vh = _heads(v, H, dh).astype(jnp.float32)
    wh = _heads(w, H, dh)

    n_chunks = max(1, S // chunk)
    assert S % n_chunks == 0, "sequence must divide the rwkv chunk"
    csz = S // n_chunks

    def split(t):   # (B, S, H, dh) -> (n_chunks, B, csz, H, dh)
        return t.reshape(B, n_chunks, csz, H, dh).swapaxes(0, 1)

    def chunk_fn(s, inp):
        rc, kc, vc, wc = inp                              # (B, csz, H, dh)

        def step(s, t):
            r_t, k_t, v_t, w_t = t                        # (B, H, dh)
            a = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
            out = jnp.einsum("bhk,bhkv->bhv", r_t,
                             s + u[None, :, :, None] * a)
            s = w_t[..., None] * s + a
            return s, out

        s, ys = lax.scan(step, s,
                         (rc.swapaxes(0, 1), kc.swapaxes(0, 1),
                          vc.swapaxes(0, 1), wc.swapaxes(0, 1)))
        return s, ys.swapaxes(0, 1)                       # (B, csz, H, dh)

    s_f, ys = lax.scan(jax.checkpoint(chunk_fn), s0,
                       (split(rh), split(kh), split(vh), split(wh)))
    ys = ys.swapaxes(0, 1).reshape(B, S, d)               # stitch chunks
    y = ys.astype(x.dtype) * g
    out = linear(p["w_o"], y)
    return out, (s_f, x[:, -1])


def rwkv6_step(p, x_t, state, cfg: ModelConfig):
    """Single decode step.  x_t: (B, d)."""
    B, d = x_t.shape
    dh = cfg.rwkv_head_dim
    H = n_rwkv_heads(cfg)
    s, x_prev = state
    r, k, v, g, w = _proj(p, x_t, x_prev)
    rh = _heads(r, H, dh).astype(jnp.float32)
    kh = _heads(k, H, dh).astype(jnp.float32)
    vh = _heads(v, H, dh).astype(jnp.float32)
    wh = _heads(w, H, dh)
    u = p["bonus"].astype(jnp.float32)
    a = jnp.einsum("bhk,bhv->bhkv", kh, vh)
    out = jnp.einsum("bhk,bhkv->bhv", rh, s + u[None, :, :, None] * a)
    s = wh[..., None] * s + a
    y = (out.reshape(B, d).astype(x_t.dtype)) * g
    return linear(p["w_o"], y), (s, x_t)


def init_rwkv_state(cfg: ModelConfig, batch: int):
    d, dh = cfg.d_model, cfg.rwkv_head_dim
    H = n_rwkv_heads(cfg)
    return (jnp.zeros((batch, H, dh, dh), dtype=jnp.float32),
            jnp.zeros((batch, d), dtype=dtype_of(cfg)))
