"""Unified model configuration covering all ten assigned architectures.

One flat frozen dataclass; family-specific fields are optional.  The
per-layer mixer is selected from ``mixer_pattern`` cycled over layers
(e.g. RecurrentGemma's 1:2 local-attn : RG-LRU ratio is
``("rglru", "rglru", "attn")``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_expert: int | None = None      # per-expert FFN width (fine-grained MoE)
    capacity_factor: float = 1.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int | None = None

    # attention
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: int | None = None          # SWA window (None = full)
    attn_bias: bool = False
    attn_logit_softcap: float | None = None
    mixer_pattern: tuple = ("attn",)           # cycled over layers

    # families
    moe: MoEConfig | None = None
    rwkv_head_dim: int = 64                    # rwkv6 head size
    rglru_conv_width: int = 4
    rglru_d_rnn: int | None = None             # lru width (default d_model)

    # encoder-decoder (whisper): encoder layers share d_model
    n_encoder_layers: int = 0
    encoder_seq: int = 1500                    # precomputed frame count (stub)

    # vlm (llava): stub patch embeddings prepended to the token stream
    n_patches: int = 0

    # misc
    act: Literal["swiglu", "gelu"] = "swiglu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True                         # per-block activation ckpt
    # §Perf levers (beyond-paper; defaults = paper-faithful baseline)
    flash_triangle: bool = False               # skip masked causal tiles
    remat_policy: str = "full"                 # "full" | "dots"
    kv_quant: bool = False                     # int8 KV cache (decode)
    # paper feature: route embedding/MoE gathers through the inline
    # prefetcher kernels where a single-core Pallas path is usable.
    use_pallas_prefetch: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else (
            self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 (Megatron-style) so the logits/vocab
        dim shards on a 16/32-way model axis (whisper's 51866 would
        otherwise replicate a 13 GB/device logits tensor).  Padding
        columns are masked to -inf in ``unembed``; labels never hit them.
        """
        return -(-self.vocab_size // 256) * 256

    def mixer_of(self, layer: int) -> str:
        return self.mixer_pattern[layer % len(self.mixer_pattern)]

    @property
    def attn_free(self) -> bool:
        return all(m != "attn" for m in self.mixer_pattern)

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: attention-free, hybrid-local or SWA."""
        return self.attn_free or self.sliding_window is not None

    # ---- parameter counting (for 6·N·D roofline bookkeeping) -------------
    def param_count(self, active_only: bool = False) -> int:
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        dh, Hq, Hkv = self.head_dim, self.n_heads, self.n_kv_heads
        n = 0
        embed = V * d
        n += embed if self.tie_embeddings else 2 * embed
        for layer in range(self.n_layers):
            mixer = self.mixer_of(layer)
            if mixer == "attn":
                n += d * Hq * dh + 2 * d * Hkv * dh + Hq * dh * d
                if self.qk_norm:
                    n += 2 * dh
            elif mixer == "rwkv6":
                n += 4 * d * d + d * d          # r,k,v,g,out
                n += 2 * 32 * d                 # ddlerp/decay loras (approx)
            elif mixer == "rglru":
                dr = self.rglru_d_rnn or d
                n += 2 * d * dr + dr * d        # in/gate/out projections
                n += self.rglru_conv_width * dr + 2 * dr
            if self.moe is not None:
                de = self.moe.d_expert or ff
                routed = self.moe.n_experts * 3 * d * de
                shared = self.moe.n_shared * 3 * d * de
                router = d * self.moe.n_experts
                if active_only:
                    routed = self.moe.top_k * 3 * d * de
                n += routed + shared + router
            else:
                mult = 3 if self.act == "swiglu" else 2
                n += mult * d * ff
            n += 2 * d                          # norms
        n += d
        if self.n_encoder_layers:
            per = d * Hq * dh + 2 * d * Hkv * dh + Hq * dh * d + 3 * d * ff + 2 * d
            n += self.n_encoder_layers * per
            # decoder cross-attention
            n += self.n_layers * (d * Hq * dh + 2 * d * Hkv * dh + Hq * dh * d + d)
        return int(n)


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 64,
            n_heads: int = 4, n_kv_heads: int | None = None,
            d_ff: int = 128, vocab: int = 512) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kv = n_kv_heads if n_kv_heads is not None else max(1, min(
        cfg.n_kv_heads, n_heads))
    kw = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=kv, d_ff=d_ff, vocab_size=vocab, d_head=None,
        remat=False,
    )
    if cfg.moe is not None:
        # capacity_factor 4.0: no token drops at smoke scale, so
        # prefill-vs-decode equivalence is exact (capacity dropping is
        # batch-context-dependent by design; full configs keep cf=1.0)
        kw["moe"] = MoEConfig(n_experts=min(8, cfg.moe.n_experts),
                              top_k=min(2, cfg.moe.top_k),
                              n_shared=min(1, cfg.moe.n_shared),
                              d_expert=32 if cfg.moe.d_expert else None,
                              capacity_factor=4.0)
    if cfg.sliding_window is not None:
        kw["sliding_window"] = 16
    if cfg.n_encoder_layers:
        kw["n_encoder_layers"] = 2
        kw["encoder_seq"] = 8
    if cfg.n_patches:
        kw["n_patches"] = 4
    if cfg.rglru_d_rnn:
        kw["rglru_d_rnn"] = d_model
    return dataclasses.replace(cfg, **kw)
