"""RG-LRU recurrent block (RecurrentGemma / Griffin).

    a_t  = exp(-c · softplus(Λ) · σ(W_a x_t))          (gated decay)
    h_t  = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

preceded by a short temporal conv (width 4) and wrapped in a gated
linear unit, following arXiv:2402.19427.  Like RWKV, the recurrence is a
data-dependent loop-carried cycle — chasing under the paper's taxonomy —
so the inline prefetcher applies to this arch only at the embedding and
local-attention layers (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import dtype_of, init_linear, linear

_C = 8.0


def init_rglru(key, cfg: ModelConfig):
    d, dtype = cfg.d_model, dtype_of(cfg)
    dr = cfg.rglru_d_rnn or d
    W = cfg.rglru_conv_width
    ks = jax.random.split(key, 6)
    return {
        "w_x": init_linear(ks[0], d, dr, dtype),       # input branch
        "w_gate": init_linear(ks[1], d, dr, dtype),    # GLU gate branch
        "w_out": init_linear(ks[2], dr, d, dtype),
        "conv": (jax.random.normal(ks[3], (W, dr), jnp.float32)
                 * 0.1).astype(dtype),
        "w_a": init_linear(ks[4], dr, dr, dtype),      # recurrence gate
        "w_i": init_linear(ks[5], dr, dr, dtype),      # input gate
        "lam": jnp.full((dr,), 0.7, dtype=jnp.float32),  # Λ (softplus'd)
    }


def _conv1d(p, x, conv_state=None):
    """Causal temporal conv, width W.  x: (B, S, dr)."""
    W = p["conv"].shape[0]
    if conv_state is None:
        x_pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([conv_state, x], axis=1)
    out = sum(x_pad[:, i:i + x.shape[1]] * p["conv"][i]
              for i in range(W))
    return out, x_pad[:, -(W - 1):]


def _gates(p, u):
    a_log = -_C * jax.nn.softplus(p["lam"]) * jax.nn.sigmoid(
        linear(p["w_a"], u).astype(jnp.float32))
    a = jnp.exp(a_log)
    gated_in = jax.nn.sigmoid(linear(p["w_i"], u)) * u
    scale = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, (scale * gated_in.astype(jnp.float32))


def rglru_seq(p, x, cfg: ModelConfig, state=None):
    """x: (B, S, d) -> (B, S, d).  state = (h, conv_state)."""
    B, S, d = x.shape
    dr = cfg.rglru_d_rnn or d
    u = linear(p["w_x"], x)                               # (B, S, dr)
    h0 = (jnp.zeros((B, dr), jnp.float32) if state is None else state[0])
    conv_state = None if state is None else state[1]
    u, conv_state = _conv1d(p, u, conv_state)
    a, bx = _gates(p, u)                                  # (B, S, dr) f32

    def step(h, inp):
        a_t, b_t = inp
        h = a_t * h + b_t
        return h, h

    h_f, hs = lax.scan(step, h0, (a.swapaxes(0, 1), bx.swapaxes(0, 1)))
    hs = hs.swapaxes(0, 1).astype(x.dtype)                # (B, S, dr)
    gate = jax.nn.gelu(linear(p["w_gate"], x))
    return linear(p["w_out"], hs * gate), (h_f, conv_state)


def rglru_step(p, x_t, state, cfg: ModelConfig):
    """x_t: (B, d); state = (h, conv_state (B, W-1, dr))."""
    h, conv_state = state
    u = linear(p["w_x"], x_t)[:, None]                    # (B, 1, dr)
    u, conv_state = _conv1d(p, u, conv_state)
    a, bx = _gates(p, u)
    h = a[:, 0] * h + bx[:, 0]
    gate = jax.nn.gelu(linear(p["w_gate"], x_t))
    out = linear(p["w_out"], h.astype(x_t.dtype) * gate)
    return out, (h, conv_state)


def init_rglru_state(cfg: ModelConfig, batch: int):
    dr = cfg.rglru_d_rnn or cfg.d_model
    W = cfg.rglru_conv_width
    return (jnp.zeros((batch, dr), jnp.float32),
            jnp.zeros((batch, W - 1, dr), dtype=dtype_of(cfg)))
