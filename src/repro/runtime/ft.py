"""Fault tolerance: straggler detection + elastic re-mesh.

On a real multi-pod deployment these hooks sit on the coordinator:

* :class:`StragglerWatchdog` keeps a per-host EWMA of step wall-time and
  flags hosts whose last step exceeded ``threshold ×`` the fleet median —
  the scheduler can then drain the host and trigger an elastic re-mesh.
  The detection logic is pure and fully unit-testable off-hardware.
* :class:`ElasticController` owns recovery policy: given a new device
  count it proposes the nearest valid mesh (keeping the "model" axis —
  changing TP degree would resize weight shards, which we only allow at
  checkpoint-restore boundaries) and restores the latest checkpoint with
  the new shardings (`checkpoint.restore` reshards at load time).
"""
from __future__ import annotations

import dataclasses
import math
import statistics


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 2.0       # × fleet median
    alpha: float = 0.3           # EWMA coefficient
    _ewma: dict = dataclasses.field(default_factory=dict)

    def observe(self, host: str, step_time_s: float) -> None:
        prev = self._ewma.get(host)
        self._ewma[host] = (step_time_s if prev is None
                            else self.alpha * step_time_s
                            + (1 - self.alpha) * prev)

    def stragglers(self) -> list[str]:
        if len(self._ewma) < 2:
            return []
        med = statistics.median(self._ewma.values())
        return [h for h, t in self._ewma.items()
                if t > self.threshold * med]

    def healthy(self) -> bool:
        return not self.stragglers()


@dataclasses.dataclass
class ElasticController:
    model_axis: int              # fixed TP degree
    min_data: int = 1

    def propose_mesh(self, n_devices: int) -> tuple[int, int]:
        """Largest (data, model) grid with the fixed model axis that fits
        ``n_devices`` — drop stragglers, keep training."""
        data = n_devices // self.model_axis
        if data < self.min_data:
            raise RuntimeError(
                f"not enough devices ({n_devices}) for model axis "
                f"{self.model_axis}")
        return (data, self.model_axis)

    def batch_for(self, global_batch: int, data: int) -> int:
        """Keep per-replica batch constant; shrink the global batch to the
        nearest multiple when replicas are lost (synchronous elastic)."""
        per = max(1, global_batch // max(data, 1))
        return per * data
