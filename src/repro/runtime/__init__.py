from .train_loop import Trainer, TrainConfig, make_train_step  # noqa: F401
from .ft import StragglerWatchdog, ElasticController  # noqa: F401
