"""Distributed training loop: pjit step, microbatch accumulation,
checkpoint/restart, straggler watchdog, elastic re-mesh.

``make_train_step`` builds the jitted step used both for real training
(examples/, tests/) and for the multi-pod dry-run (lowered with
ShapeDtypeStructs).  The step is pure:

    (params, opt_state, ef_residual, batch, step) ->
        (params', opt_state', ef_residual', metrics)

with loss/grad in one pass, optional gradient compression with error
feedback (cross-pod traffic), AdamW, and WSD schedule.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from .. import models
from ..checkpoint import CheckpointManager, latest_step, restore
from ..data import SyntheticLM
from ..optim import (AdamWConfig, adamw_init, adamw_update, compress_grads,
                     init_error_feedback, wsd_schedule)
from ..parallel import batch_pspec, make_shardings, param_pspecs
from .ft import StragglerWatchdog


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1            # gradient accumulation
    grad_compression: bool = True    # bf16 + error feedback
    gathered_weights: bool = False   # AG weights once/step, not per use
    peak_lr: float = 3e-4
    warmup: int = 100
    ckpt_every: int = 50
    keep_ckpts: int = 3
    adamw: AdamWConfig = AdamWConfig()


def make_train_step(cfg, tcfg: TrainConfig):
    """Returns step_fn(params, opt_state, residual, batch, step)."""

    def loss_fn(params, batch):
        loss, metrics = models.train_loss(params, batch, cfg)
        return loss, metrics

    def step_fn(params, opt_state, residual, batch, step):
        # gathered-weights mode: the model consumes a model-axis-only
        # resharded view; XLA hoists the (scan-invariant) gather out of
        # the microbatch loop and reduce-scatters the gradient once at
        # the constraint boundary.  The optimizer still updates the 2-D
        # shards.
        if tcfg.gathered_weights:
            from ..parallel import gather_weights
            params_use = gather_weights(params)
        else:
            params_use = params
        if tcfg.microbatches > 1:
            mb = tcfg.microbatches

            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

            batches = jax.tree.map(split, batch)

            def acc(carry, mbatch):
                g_acc, l_acc = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params_use, mbatch)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc, (zeros, 0.0), batches)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb
            metrics = {"loss": loss, "aux": jnp.float32(0)}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params_use, batch)

        grads, residual = compress_grads(grads, residual,
                                         tcfg.grad_compression)
        lr = wsd_schedule(step, peak_lr=tcfg.peak_lr, warmup=tcfg.warmup)
        params, opt_state, gnorm = adamw_update(
            grads, opt_state, params, tcfg.adamw, lr=lr)
        metrics = dict(metrics, gnorm=gnorm, lr=lr)
        return params, opt_state, residual, metrics

    return step_fn


class Trainer:
    """End-to-end driver with restart/elasticity; used by examples/tests."""

    def __init__(self, cfg, tcfg: TrainConfig, mesh, *, seq_len: int,
                 global_batch: int, ckpt_dir: str | None = None, seed=0):
        self.cfg, self.tcfg, self.mesh = cfg, tcfg, mesh
        self.pipeline = SyntheticLM(cfg.vocab_size, seq_len, global_batch,
                                    seed=seed)
        self.step = 0
        self.watchdog = StragglerWatchdog()
        self.ckpt = (CheckpointManager(ckpt_dir, keep=tcfg.keep_ckpts)
                     if ckpt_dir else None)

        with mesh:
            params = models.init_params(cfg, jax.random.PRNGKey(seed))
            self.pspecs = param_pspecs(params, mesh)
            shardings = make_shardings(self.pspecs, mesh)
            self.params = jax.tree.map(
                lambda x, s: jax.device_put(x, s), params, shardings)
            self.opt_state = adamw_init(self.params)
            self.residual = init_error_feedback(self.params)
        self._maybe_resume()
        self._step_fn = jax.jit(
            make_train_step(cfg, tcfg), donate_argnums=(0, 1, 2))

    # -- fault tolerance ---------------------------------------------------
    def _maybe_resume(self):
        if self.ckpt is None:
            return
        last = self.ckpt.latest()
        if last is None:
            return
        shardings = make_shardings(self.pspecs, self.mesh)
        self.params = restore(self.ckpt.dir, last, self.params, shardings)
        self.opt_state = restore(self.ckpt.dir, last, self.opt_state) \
            if _has(self.ckpt.dir, last, "opt") else self.opt_state
        self.step = last

    def save(self):
        if self.ckpt is not None:
            self.ckpt.save_async(self.step, self.params,
                                 extra=self.pipeline.state(self.step))

    # -- loop ----------------------------------------------------------------
    def run(self, n_steps: int, log_every: int = 10):
        history = []
        with self.mesh:
            for _ in range(n_steps):
                t0 = time.perf_counter()
                batch = self.pipeline.batch(self.step)
                (self.params, self.opt_state, self.residual,
                 metrics) = self._step_fn(self.params, self.opt_state,
                                          self.residual, batch,
                                          jnp.int32(self.step))
                dt = time.perf_counter() - t0
                self.watchdog.observe("host0", dt)
                self.step += 1
                if self.step % log_every == 0 or self.step == 1:
                    history.append((self.step, float(metrics["loss"]), dt))
                if self.ckpt and self.step % self.tcfg.ckpt_every == 0:
                    self.save()
        if self.ckpt:
            self.save()
            self.ckpt.wait()
        return history


def _has(d, step, _kind):
    return False  # opt-state resume is exercised separately in tests
