"""Batched serving engine: prefill + decode with KV cache.

The decode loop is the serving-side home of the paper's technique: each
step embeds the sampled token (irregular vocab gather) and reads the KV
cache.  With the paged allocator the KV read is ``pool[page_table[...]]``
— the indirection the ``paged_kv`` kernel prefetches.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .. import models


@dataclasses.dataclass
class ServeEngine:
    cfg: object
    params: object
    capacity: int = 256

    def prefill(self, tokens, extra=None):
        batch = {"tokens": tokens}
        if extra:
            batch.update(extra)
        logits, cache = models.prefill(self.params, batch, self.cfg,
                                       capacity=self.capacity)
        return logits, cache

    def decode(self, cache, last_logits, n_steps: int):
        """Greedy decode ``n_steps`` tokens for the whole batch."""
        tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)

        def body(carry, _):
            tok, cache = carry
            logits, cache = models.decode_step(self.params, cache, tok,
                                               self.cfg)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return (nxt, cache), tok

        (_, cache), toks = jax.lax.scan(body, (tok, cache), None,
                                        length=n_steps)
        return toks.swapaxes(0, 1), cache   # (B, n_steps)


def greedy_generate(cfg, params, prompt_tokens, n_new: int,
                    capacity: int | None = None, extra=None):
    """Convenience: prefill a prompt batch then greedy-decode n_new."""
    cap = capacity or (prompt_tokens.shape[1] + n_new + 1)
    eng = ServeEngine(cfg, params, capacity=cap)
    logits, cache = eng.prefill(prompt_tokens, extra=extra)
    toks, _ = eng.decode(cache, logits, n_new)
    return toks
