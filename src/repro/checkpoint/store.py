"""Fault-tolerant checkpointing: atomic, async, reshard-on-load.

Layout: ``<dir>/step_<n>/`` holding one ``arrays.npz`` (keys are
parameter paths) plus ``manifest.json``.  Writes go to ``.tmp-step_<n>``
and are renamed into place, so a crash mid-write never corrupts the
latest checkpoint; ``latest_step`` only trusts directories with a
manifest.  ``restore`` rebuilds the target pytree structure and
``device_put``s each leaf with the *requested* sharding — which is what
makes elastic re-mesh (restore onto a different mesh shape) a pure
load-time operation.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import jax.tree_util as jtu
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jtu.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jtu.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    """Atomic synchronous save.  Returns the final directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = os.path.join(ckpt_dir, f".tmp-step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = jtu.tree_flatten_with_path(tree)[0]
    arrays = {}
    for p, v in flat:
        arr = np.asarray(v)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = arr.astype(np.float32)   # npz-safe; restore recasts
        arrays[_path_str(p)] = arr
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "time": time.time(),
                "n_arrays": len(arrays), "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree, shardings=None):
    """Load into the structure of ``target_tree``; optionally reshard."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    flat, tree = jtu.tree_flatten_with_path(target_tree)
    shard_flat = (jtu.tree_leaves(shardings) if shardings is not None
                  else [None] * len(flat))
    leaves = []
    for (path, ref), shd in zip(flat, shard_flat):
        arr = arrays[_path_str(path)]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch at {_path_str(path)}: "
                             f"{arr.shape} vs {ref.shape}")
        arr = arr.astype(ref.dtype)
        leaves.append(jax.device_put(arr, shd) if shd is not None
                      else jax.device_put(arr))
    return jtu.tree_unflatten(jtu.tree_structure(target_tree), leaves)


class CheckpointManager:
    """Async writer + retention.  ``save_async`` snapshots to host memory
    synchronously (cheap) and writes in a background thread so the train
    loop never blocks on disk."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree, extra=None):
        host = jax.tree.map(np.asarray, tree)   # snapshot before mutation
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra), daemon=True)
        self._thread.start()

    def _write(self, step, host_tree, extra):
        save(self.dir, step, host_tree, extra)
        self._gc()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and os.path.exists(
                os.path.join(self.dir, n, "manifest.json")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def latest(self):
        return latest_step(self.dir)
