from .store import (CheckpointManager, latest_step, restore,  # noqa: F401
                    save)
