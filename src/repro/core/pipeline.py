"""Carrot-and-horse inline prefetch transform (paper §4, Fig. 6).

Given a scan loop whose body contains a *prefetchable* DIL (per
:mod:`repro.core.dil`), rewrite it so that a duplicated copy of the DIL's
backward slice (the **carrot**) runs ``k`` iterations ahead of the main
computation (the **horse**) and performs the load early, while the horse
consumes the value loaded ``k`` iterations ago from a ring buffer.

Phase mapping (paper -> here):

* **save**       — the carrot gets its own carry slots in the rewritten
                   scan state (fresh "registers"); nothing to spill.
* **head start** — a length-``k`` warm-up scan runs the carrot alone and
                   fills the ``k``-deep ring buffer of loaded values.
* **stay ahead** — the main scan: at step ``i`` the horse consumes
                   ``ring[i % k]`` (the value for iteration ``i``), the
                   carrot computes the index for iteration ``i + k``,
                   performs that load, and overwrites ``ring[i % k]``.
* **join**       — for ``i + k >= n`` the carrot's loads land in ring
                   slots that are never read again; indices may run off
                   the end of the data (the x-stream is wrapped), which
                   is harmless: those values are dead.
* **restore**    — the carrot state is simply dropped from the final
                   carry.

The rewritten loop is **bit-exact** with ``lax.scan(body_fn, init, xs)``:
the horse executes the original body unchanged except that the target
load's result is injected, and the injected value is produced by an exact
duplicate of the original index computation.

On TPU, the mechanism by which this wins is the same as the paper's: the
load for iteration ``i + k`` has no data dependence on iteration ``i``'s
compute, so the scheduler overlaps the (HBM round-trip) gather with
compute — the pure-JAX analogue of issuing ``prefetcht0`` ``k``
iterations early.  The Pallas kernels in :mod:`repro.kernels` implement
the same schedule with explicit async DMA for the cases where we control
the kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
from jax import lax

from . import dil, ir


# ---------------------------------------------------------------------------
# Manual API: the user supplies the carrot/gather/horse split.
# ---------------------------------------------------------------------------

def pipelined_scan(carrot_fn: Callable, gather_fn: Callable,
                   horse_fn: Callable, init_carrot, init_carry, xs,
                   *, prefetch_distance: int, length: int | None = None,
                   carrot_xs=None):
    """Software-pipelined scan with an explicit carrot/horse split.

    ``carrot_fn(carrot_state, x) -> (carrot_state', index)``
    ``gather_fn(index) -> value``                (the DIL, hoisted)
    ``horse_fn(carry, x, value) -> (carry', y)`` (original body, value injected)

    Semantically equal to::

        def body(c, x):
            s, idx = carrot... ; v = gather_fn(idx); return horse_fn(c, x, v)
        lax.scan(body, init_carry, xs)

    but with the gather running ``prefetch_distance`` iterations ahead.
    ``carrot_xs`` optionally provides a different x-stream for the carrot
    (defaults to ``xs`` rolled by ``k``, wrapping — the join-phase values
    are dead so wrapping is safe).
    """
    xs_leaves = jtu.tree_leaves(xs)
    if length is None:
        if not xs_leaves:
            raise ValueError("length required when xs is None")
        length = xs_leaves[0].shape[0]
    n = int(length)
    k = max(1, min(int(prefetch_distance), n))

    if carrot_xs is None and xs_leaves:
        carrot_xs = jtu.tree_map(lambda a: jnp.roll(a, -k, axis=0), xs)

    def take_prefix(tree, lo, hi):
        return jtu.tree_map(lambda a: a[lo:hi], tree)

    # ---- head start: fill the ring ---------------------------------------
    def warm_step(state, x):
        state, idx = carrot_fn(state, x)
        return state, idx

    warm_xs = take_prefix(xs, 0, k) if xs_leaves else None
    carrot_state, warm_idx = lax.scan(warm_step, init_carrot, warm_xs,
                                      length=k)
    ring = jax.vmap(gather_fn)(warm_idx)          # [k, ...] loaded values

    # ---- stay ahead + join ------------------------------------------------
    iters = jnp.arange(n, dtype=jnp.int32)

    def step(state, inp):
        carry, cstate, ring = state
        i, x, x_ahead = inp
        slot = lax.rem(i, jnp.int32(k))
        value = jtu.tree_map(
            lambda r: lax.dynamic_index_in_dim(r, slot, keepdims=False), ring)
        cstate, idx_ahead = carrot_fn(cstate, x_ahead)
        v_ahead = gather_fn(idx_ahead)
        ring = jtu.tree_map(
            lambda r, v: lax.dynamic_update_index_in_dim(r, v, slot, axis=0),
            ring, v_ahead)
        carry, y = horse_fn(carry, x, value)
        return (carry, cstate, ring), y

    scan_xs = (iters, xs, carrot_xs) if xs_leaves else (iters, xs, xs)
    (carry, _, _), ys = lax.scan(step, (init_carry, carrot_state, ring),
                                 scan_xs, length=n)
    return carry, ys


# ---------------------------------------------------------------------------
# Automatic API: split derived from the DIL screen.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PrefetchPlan:
    body: dil.FlatLoopBody
    report: dil.LoopReport
    target: dil.LoadReport
    slice_ops: list          # carrot ops
    carry_positions: list    # carry slots the carrot owns copies of
    index_atoms: list        # atoms holding the load's index operand(s)
    table_ops: list = dataclasses.field(default_factory=list)
    # ops producing the (loop-invariant) table operand, e.g. a column
    # slice of a closed-over array; evaluated inside gather_fn and
    # hoisted out of the loop by XLA LICM

    def describe(self) -> str:
        return (f"target=op{self.target.op_idx} ({self.target.prim}, "
                f"table={self.target.table_shape}) "
                f"carrot_ops={len(self.slice_ops)} "
                f"carrot_carries={self.carry_positions}")


def plan_prefetch(body_fn: Callable, init_carry, x_example, *,
                  target_op: int | None = None,
                  delinquent_bytes: int = 4 * 2**20) -> PrefetchPlan:
    """Run the DIL screen and build the carrot extraction plan."""
    body = dil.flatten_loop_body(body_fn, init_carry, x_example)
    report = dil.screen_body(body, delinquent_bytes=delinquent_bytes)
    if target_op is not None:
        cands = [l for l in report.loads if l.op_idx == target_op]
        if not cands or not cands[0].prefetchable:
            raise ValueError(f"op {target_op} is not a prefetchable DIL:\n"
                             + report.summary())
        target = cands[0]
    else:
        crit = report.critical_targets
        if not crit:
            raise ValueError("no prefetchable DIL found:\n" + report.summary())
        target = max(crit, key=lambda l: l.table_bytes)

    fn = body.fn
    op = fn.ops[target.op_idx]
    analysis = dil._LoopAnalysis(
        fn, carry_in_ids=fn.invars[:body.n_carry],
        carry_out_atoms=fn.outvals[:body.n_carry],
        xs_ids=fn.invars[body.n_carry:], stable_ids=set())
    idx_atoms = dil._index_atoms(op)
    roots = [a for a in idx_atoms if isinstance(a, int)]
    slice_ops, carries = analysis.closed_slice(roots)
    # The load's table operand must be loop-INVARIANT (the paper's
    # "statically inferable store addresses" restriction) but may be
    # *computed* from consts (e.g. a column slice of a closed-over
    # array); those ops are hoisted into gather_fn.
    table_atom = op.invals[0]
    table_ops: list = []
    if isinstance(table_atom, int) and table_atom not in fn.const_env:
        table_ops = ir.backward_slice(fn, [table_atom])
        free = ir.slice_free_inputs(fn, table_ops, [table_atom])
        varying = free & (set(fn.invars))
        if varying:
            raise ValueError(
                "table operand depends on loop state; cannot hoist load")
    return PrefetchPlan(body, report, target, slice_ops, sorted(carries),
                        idx_atoms, table_ops)


def _build_callables(plan: PrefetchPlan):
    fn = plan.body.fn
    n_c = plan.body.n_carry
    carry_ids = fn.invars[:n_c]
    xs_ids = fn.invars[n_c:]
    op = fn.ops[plan.target.op_idx]
    pos = plan.carry_positions

    def carrot_fn(cstate, x_flat):
        env = {}
        for p, v in zip(pos, cstate):
            env[carry_ids[p]] = v
        for vid, v in zip(xs_ids, x_flat or ()):
            env[vid] = v
        fn.eval_ops(env, plan.slice_ops)
        idx = tuple(fn._read(env, a) for a in plan.index_atoms)
        new_state = tuple(fn._read(env, fn.outvals[p]) for p in pos)
        return new_state, idx

    def gather_fn(idx):
        env = {}
        for a, v in zip(plan.index_atoms, idx):
            if isinstance(a, int):
                env[a] = v
        fn.eval_ops(env, list(plan.table_ops) + [op])
        assert len(op.outs) == 1, "multi-output loads unsupported"
        return env[op.outs[0]]

    def horse_fn(carry_flat, x_flat, value):
        env = dict(zip(carry_ids, carry_flat))
        env.update(zip(xs_ids, x_flat or ()))
        fn.eval_ops(env, fn.ops, inject={op.idx: value})
        outs = [fn._read(env, a) for a in fn.outvals]
        return tuple(outs[:n_c]), tuple(outs[n_c:])

    def init_carrot_from(carry_flat):
        return tuple(carry_flat[p] for p in pos)

    return carrot_fn, gather_fn, horse_fn, init_carrot_from


def prefetch_scan(body_fn: Callable, init_carry, xs, *,
                  prefetch_distance: int = 8,
                  target_op: int | None = None,
                  delinquent_bytes: int = 4 * 2**20,
                  length: int | None = None):
    """Drop-in replacement for ``lax.scan(body_fn, init, xs)`` that
    automatically extracts and pipelines the critical prefetchable DIL.

    Raises ``ValueError`` if the screen finds no prefetchable DIL (i.e.
    the loop is either regular — leave it to the hardware pipeline — or
    chasing/control-dependent — the paper's own exclusions).
    """
    x_example = jtu.tree_map(lambda a: a[0], xs) if xs is not None else None
    plan = plan_prefetch(body_fn, init_carry, x_example,
                         target_op=target_op,
                         delinquent_bytes=delinquent_bytes)
    carrot_fn, gather_fn, horse_fn, init_carrot_from = _build_callables(plan)

    carry_flat, carry_tree = jtu.tree_flatten(init_carry)
    x_leaves_tree = None
    if xs is not None:
        xs_flat, xs_tree = jtu.tree_flatten(xs)
        x_leaves_tree = xs_tree
    else:
        xs_flat = []

    def carrot_flat(cstate, x_flat):
        return carrot_fn(cstate, x_flat)

    def horse_flat(carry, x_flat, value):
        return horse_fn(carry, x_flat, value)

    carry, ys_flat = pipelined_scan(
        carrot_flat, gather_fn, horse_flat,
        init_carrot_from(carry_flat), tuple(carry_flat),
        tuple(xs_flat) if xs_flat else None,
        prefetch_distance=prefetch_distance, length=length)

    final_carry = jtu.tree_unflatten(carry_tree, list(carry))
    ys = jtu.tree_unflatten(plan.body.y_tree, list(ys_flat))
    return final_carry, ys
