"""Prefetch-distance planning (the paper's statically-controlled ``k``).

The paper picks ``k`` empirically by sweeping powers of two and observes
(§5.2.2) that speedup is stable once the lookahead clears the dynamic
instruction window, and that over-large ``k`` loses opportunity when the
loop trip count is small.  On TPU the same trade-off is governed by
hardware constants we can napkin-math directly:

* the prefetch must hide one HBM round trip:   ``k >= latency / t_iter``
* the ring must fit the VMEM budget:           ``k * row_bytes <= vmem``
* lookahead beyond the trip count is wasted:   ``k <= trip_count``

``plan_prefetch_distance`` returns the smallest power of two satisfying
all three (powers of two for the paper's shift-not-multiply convenience;
arbitrary ``k`` works everywhere in this codebase).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """TPU v5e per-chip constants (assignment-specified)."""
    peak_flops: float = 197e12          # bf16 FLOP/s
    hbm_bw: float = 819e9               # B/s
    ici_bw: float = 50e9                # B/s per link
    hbm_latency: float = 1.0e-6         # s, one async-copy round trip
    vmem_bytes: int = 64 * 2**20        # usable VMEM budget (half of 128MiB)
    hbm_bytes: int = 16 * 2**30         # v5e HBM capacity


V5E = HardwareModel()


def iter_time(flops_per_iter: float, hbm_bytes_per_iter: float,
              hw: HardwareModel = V5E) -> float:
    """Roofline execution time of one loop iteration (s)."""
    return max(flops_per_iter / hw.peak_flops,
               hbm_bytes_per_iter / hw.hbm_bw,
               1e-9)


def plan_prefetch_distance(row_bytes: int, flops_per_iter: float,
                           hbm_bytes_per_iter: float, *,
                           trip_count: int | None = None,
                           hw: HardwareModel = V5E,
                           power_of_two: bool = True,
                           k_min: int = 2, k_max: int = 256) -> int:
    """Choose the prefetch distance ``k``.

    ``row_bytes``            bytes fetched per prefetch (one ring slot)
    ``flops_per_iter``       compute per loop iteration
    ``hbm_bytes_per_iter``   *regular* (already-pipelined) HBM traffic per
                             iteration; the irregular row itself is excluded
                             because it is exactly what we are hiding.
    """
    t = iter_time(flops_per_iter, hbm_bytes_per_iter, hw)
    k_latency = math.ceil(hw.hbm_latency / t)
    k_vmem = max(1, hw.vmem_bytes // max(row_bytes, 1))
    k = max(k_min, k_latency)
    k = min(k, k_vmem, k_max)
    if trip_count is not None:
        k = min(k, max(1, trip_count))
    if power_of_two:
        k = 1 << max(0, (k - 1).bit_length())
        k = min(k, k_vmem, k_max)
        if trip_count is not None:
            while k > max(1, trip_count):
                k //= 2
    return max(1, k)


def ring_bytes(row_bytes: int, k: int) -> int:
    return row_bytes * k
