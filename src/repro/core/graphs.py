"""Tiny dependency-free graph utilities for the DIL screen.

The paper enumerates simple cycles of the backward slice (Johnson's
algorithm via networkx).  For the *screen* itself only membership of a
load in *some* cycle matters, which is equivalent to membership in a
non-trivial strongly connected component — so the core uses Tarjan SCC
and stays dependency-free.  ``simple_cycles`` (Johnson) is provided for
the Table-2 style reporting benchmarks.
"""
from __future__ import annotations

from typing import Hashable, Iterable, Iterator


def tarjan_scc(nodes: Iterable[Hashable],
               succ: dict[Hashable, list[Hashable]]) -> list[list[Hashable]]:
    """Iterative Tarjan strongly-connected components."""
    index: dict = {}
    low: dict = {}
    on_stack: set = set()
    stack: list = []
    sccs: list[list] = []
    counter = [0]

    for root in nodes:
        if root in index:
            continue
        work = [(root, iter(succ.get(root, ())))]
        index[root] = low[root] = counter[0]
        counter[0] += 1
        stack.append(root)
        on_stack.add(root)
        while work:
            v, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(succ.get(w, ()))))
                    advanced = True
                    break
                elif w in on_stack:
                    low[v] = min(low[v], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[v])
            if low[v] == index[v]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.append(w)
                    if w == v:
                        break
                sccs.append(comp)
    return sccs


def nodes_in_cycles(nodes: Iterable[Hashable],
                    succ: dict[Hashable, list[Hashable]]) -> set[Hashable]:
    """Nodes that belong to at least one directed cycle."""
    nodes = list(nodes)
    out: set = set()
    for comp in tarjan_scc(nodes, succ):
        if len(comp) > 1:
            out.update(comp)
        else:
            v = comp[0]
            if v in succ.get(v, ()):  # self loop
                out.add(v)
    return out


def simple_cycles(nodes: Iterable[Hashable],
                  succ: dict[Hashable, list[Hashable]],
                  limit: int = 10000) -> Iterator[list[Hashable]]:
    """Johnson-style simple cycle enumeration (via networkx if present)."""
    try:
        import networkx as nx
        g = nx.DiGraph()
        g.add_nodes_from(nodes)
        for u, vs in succ.items():
            for v in vs:
                g.add_edge(u, v)
        for i, cyc in enumerate(nx.simple_cycles(g)):
            if i >= limit:
                return
            yield cyc
    except ImportError:  # pragma: no cover - networkx is installed here
        for comp in tarjan_scc(nodes, succ):
            if len(comp) > 1 or comp[0] in succ.get(comp[0], ()):
                yield comp
