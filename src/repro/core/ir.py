"""Flat dataflow IR over jaxprs.

The paper's analysis operates on x86 machine code: a flat instruction
stream whose dataflow (through registers/memory) is recovered by dynamic
slicing.  Our analogue of "machine code" is the jaxpr.  jnp-level ops,
however, trace to *nested* ``jit`` equations (e.g. ``jnp.take`` hides its
``gather`` inside a ``jit[name=_take]`` call), so before any dataflow
analysis we inline call-like equations into a flat list of atomic ops —
the moral equivalent of disassembling through call boundaries, which is
exactly what the paper's pintool-based slicing does.

The IR is deliberately tiny:

* values are integer ids (``VarId``); literals/consts are bound in an
  environment at build time,
* an :class:`Op` is one atomic primitive application,
* :class:`FlatFn` is the flattened function: ordered ops + input ids +
  output atoms + a constant environment.

``FlatFn.eval`` re-executes any subset of the ops via ``Primitive.bind``
(the same mechanism as ``jax.core.eval_jaxpr``), which is how the carrot
(backward slice) and the horse (main body with the load's result
injected) are materialised as runnable JAX callables in
:mod:`repro.core.pipeline`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
from jax.extend import core as jcore

# Call-like primitives that are transparently inlined.  Structured control
# flow (scan/while/cond) stays atomic: it is the analogue of a nested loop
# or a branch in the paper's CFG and is handled by the screen itself.
_INLINE_PRIMS = ("jit", "pjit", "closed_call", "core_call", "custom_jvp_call",
                 "custom_vjp_call", "remat", "checkpoint", "custom_vjp_call_jaxpr")

CONTROL_PRIMS = ("cond", "while", "scan")

VarId = int


@dataclasses.dataclass(frozen=True)
class Lit:
    """An inline literal operand (scalar literals in jaxprs)."""
    val: Any


@dataclasses.dataclass
class Op:
    prim: Any                 # jax Primitive
    invals: list[Any]         # VarId | Lit
    outs: list[VarId]
    params: dict
    # index into FlatFn.ops — filled by FlatFn
    idx: int = -1

    @property
    def name(self) -> str:
        return self.prim.name

    def in_ids(self) -> list[VarId]:
        return [a for a in self.invals if isinstance(a, int)]


class FlatFn:
    """A flattened jaxpr: atomic ops in topological order."""

    def __init__(self) -> None:
        self.ops: list[Op] = []
        self.n_vars: int = 0
        self.invars: list[VarId] = []
        self.outvals: list[Any] = []          # VarId | Lit
        self.const_env: dict[VarId, Any] = {} # VarId -> concrete array
        self.avals: dict[VarId, Any] = {}     # VarId -> aval
        self.producer: dict[VarId, Op] = {}

    # -- construction ------------------------------------------------------
    def fresh(self, aval=None) -> VarId:
        vid = self.n_vars
        self.n_vars += 1
        if aval is not None:
            self.avals[vid] = aval
        return vid

    def add_op(self, prim, invals, out_avals, params) -> list[VarId]:
        outs = [self.fresh(a) for a in out_avals]
        op = Op(prim, list(invals), outs, dict(params), idx=len(self.ops))
        self.ops.append(op)
        for o in outs:
            self.producer[o] = op
        return outs

    # -- evaluation --------------------------------------------------------
    def _read(self, env: dict, atom) -> Any:
        if isinstance(atom, Lit):
            return atom.val
        if atom in env:
            return env[atom]
        if atom in self.const_env:
            return self.const_env[atom]
        raise KeyError(f"unbound var id {atom}")

    def eval_ops(self, env: dict, ops: Sequence[Op],
                 inject: dict[int, Any] | None = None) -> dict:
        """Execute ``ops`` in order, updating ``env`` in place.

        ``inject`` maps op.idx -> value(s): instead of executing that op,
        bind its outputs to the given value(s).  This is how the horse
        receives the prefetched load value.
        """
        inject = inject or {}
        for op in ops:
            if op.idx in inject:
                vals = inject[op.idx]
                if not isinstance(vals, (list, tuple)):
                    vals = [vals]
                for o, v in zip(op.outs, vals):
                    env[o] = v
                continue
            invals = [self._read(env, a) for a in op.invals]
            out = op.prim.bind(*invals, **op.params)
            if not op.prim.multiple_results:
                out = [out]
            for o, v in zip(op.outs, out):
                env[o] = v
        return env

    def eval(self, *args, ops: Sequence[Op] | None = None,
             inject: dict[int, Any] | None = None) -> list[Any]:
        assert len(args) == len(self.invars), (len(args), len(self.invars))
        env = dict(zip(self.invars, args))
        self.eval_ops(env, self.ops if ops is None else ops, inject)
        return [self._read(env, a) for a in self.outvals]


def _sub_jaxpr(eqn) -> jcore.ClosedJaxpr | None:
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        sub = eqn.params.get(key)
        if sub is not None:
            if isinstance(sub, jcore.Jaxpr):
                sub = jcore.ClosedJaxpr(sub, ())
            return sub
    return None


def flatten_closed_jaxpr(closed: jcore.ClosedJaxpr) -> FlatFn:
    """Recursively inline call-like eqns into a flat op list."""
    fn = FlatFn()

    def bind_const(val, aval) -> VarId:
        vid = fn.fresh(aval)
        fn.const_env[vid] = val
        return vid

    def go(jaxpr: jcore.Jaxpr, consts, in_atoms: list[Any]) -> list[Any]:
        env: dict[Any, Any] = {}          # jax Var -> VarId | Lit
        for var, atom in zip(jaxpr.invars, in_atoms):
            env[var] = atom
        for var, val in zip(jaxpr.constvars, consts):
            env[var] = bind_const(val, var.aval)

        def read(v):
            if isinstance(v, jcore.Literal):
                return Lit(v.val)
            return env[v]

        for eqn in jaxpr.eqns:
            sub = _sub_jaxpr(eqn) if eqn.primitive.name in _INLINE_PRIMS else None
            invals = [read(v) for v in eqn.invars]
            if sub is not None:
                outs = go(sub.jaxpr, sub.consts, invals)
                for var, atom in zip(eqn.outvars, outs):
                    env[var] = atom
            else:
                out_ids = fn.add_op(eqn.primitive, invals,
                                    [v.aval for v in eqn.outvars], eqn.params)
                for var, vid in zip(eqn.outvars, out_ids):
                    env[var] = vid
        return [read(v) for v in jaxpr.outvars]

    in_ids = [fn.fresh(v.aval) for v in closed.jaxpr.invars]
    fn.invars = in_ids
    fn.outvals = go(closed.jaxpr, closed.consts, list(in_ids))
    return fn


def flatten_fn(f: Callable, *example_args) -> tuple[FlatFn, Any]:
    """Trace ``f`` and flatten.  Returns (FlatFn, out_tree)."""
    import jax.tree_util as jtu
    flat_args, in_tree = jtu.tree_flatten(example_args)
    out_tree_box = {}

    def wrapped(*flat):
        args = jtu.tree_unflatten(in_tree, flat)
        out = f(*args)
        out_flat, out_tree = jtu.tree_flatten(out)
        out_tree_box["tree"] = out_tree
        return out_flat

    closed = jax.make_jaxpr(wrapped)(*flat_args)
    return flatten_closed_jaxpr(closed), out_tree_box["tree"]


def backward_slice(fn: FlatFn, roots: Sequence[VarId],
                   stop: Sequence[VarId] = ()) -> list[Op]:
    """All ops contributing to ``roots``, in topological (original) order.

    ``stop`` vars are treated as free inputs (slicing does not cross them).
    """
    stop_set = set(stop)
    needed: set[VarId] = set(r for r in roots if r not in stop_set)
    marked: set[int] = set()
    for op in reversed(fn.ops):
        if any(o in needed for o in op.outs):
            marked.add(op.idx)
            for a in op.in_ids():
                if a not in stop_set:
                    needed.add(a)
    return [op for op in fn.ops if op.idx in marked]


def slice_free_inputs(fn: FlatFn, ops: Sequence[Op],
                      roots: Sequence[VarId]) -> set[VarId]:
    """Ids read by the slice but not produced inside it (its live-ins)."""
    produced = {o for op in ops for o in op.outs}
    free: set[VarId] = set()
    for op in ops:
        for a in op.in_ids():
            if a not in produced and a not in fn.const_env:
                free.add(a)
    for r in roots:
        if r not in produced and r not in fn.const_env:
            free.add(r)
    return free
