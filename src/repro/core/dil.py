"""The DIL screen: delinquent-irregular-load analysis over loop bodies.

Reproduces §4.1 of the paper on jaxpr dataflow instead of x86 traces:

* **load** — a ``gather`` / ``dynamic_slice`` op with data-dependent
  indices (the jaxpr analogue of a load instruction whose address is
  computed at runtime),
* **constant / striding / irregular** — classification of the *index
  stream* feeding the load: constant-address loads read loop-invariant
  addresses; striding loads read an affine function of an affine
  induction recurrence; everything else is irregular (hash functions,
  indices streamed from data, indices produced by other loads, ...),
* **delinquent** — the gathered table cannot be VMEM/cache resident
  (``table_bytes >= delinquent_bytes``).  On TPU every irregular gather
  from an HBM-resident operand pays a full HBM round trip, so footprint
  *is* the delinquency criterion (we cannot observe ROB stalls; we do not
  need to),
* **runnable vs chasing** — no cycle of the (recurrence-closed) backward
  slice of the index contains an irregular memory op.  Cycles arise only
  through loop-carried dependencies, exactly like the paper's
  higher-IP -> lower-IP edges,
* **control independent** — the slice contains no ``cond``/``while``, and
  no ``select_n`` whose predicate depends on an in-loop load (the
  binary-search-tree exclusion of §4),
* **prefetchable** = irregular ∧ delinquent ∧ runnable ∧ control-indep,
* **critical / coalescing** — loads whose index differs from another
  load's by a constant offset are grouped; only the largest-footprint
  member of the group is kept (same-cache-line rule of §4.1).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax.extend import core as jcore

from . import ir
from .graphs import nodes_in_cycles

LOAD_PRIMS = ("gather", "dynamic_slice")

# Ops through which an address computation remains (piecewise-)affine.
# Comparisons/logic are allowed because they only ever feed ``select_n``
# predicates (branchless normalisation such as jnp.take's negative-index
# wrap); data-dependence still surfaces through the uses-xs / has-load
# checks, and genuine control dependence through the select-predicate rule.
AFFINE_PRIMS = {
    "add", "sub", "neg", "convert_element_type", "broadcast_in_dim",
    "reshape", "squeeze", "expand_dims", "slice", "transpose", "copy",
    "iota", "concatenate", "max", "min", "clamp", "stop_gradient",
    "select_n",  # select keeps *shape* affine; control-dep handled separately
    "lt", "le", "gt", "ge", "eq", "ne", "and", "or", "not", "xor",
    "is_finite", "sign", "abs",
}
# mul/div by a constant stays affine; handled specially.
SCALE_PRIMS = {"mul", "div", "shift_left", "shift_right_logical",
               "shift_right_arithmetic"}

CONSTANT, STRIDING, IRREGULAR = "constant", "striding", "irregular"


@dataclasses.dataclass
class LoadReport:
    op_idx: int
    prim: str
    table_shape: tuple
    table_dtype: Any
    table_bytes: int
    index_class: str
    delinquent: bool
    runnable: bool
    control_independent: bool
    prefetchable: bool
    critical: bool = False
    group_root: int = -1
    n_cycles_with_loads: int = 0
    reasons: list = dataclasses.field(default_factory=list)

    def row(self) -> str:
        flag = "PREFETCHABLE" if (self.prefetchable and self.critical) else (
            "coalesced" if self.prefetchable else "-")
        return (f"op{self.op_idx:>4} {self.prim:<13} table={self.table_shape!s:<16} "
                f"{self.table_bytes/2**20:8.2f}MiB {self.index_class:<9} "
                f"delinq={int(self.delinquent)} runnable={int(self.runnable)} "
                f"ctrl_indep={int(self.control_independent)} {flag}")


@dataclasses.dataclass
class LoopReport:
    flat: ir.FlatFn
    carry_in_ids: list[int]
    carry_out_atoms: list[Any]
    xs_ids: list[int]
    stable_ids: set[int]
    loads: list[LoadReport]

    @property
    def dils(self) -> list[LoadReport]:
        return [l for l in self.loads if l.index_class == IRREGULAR and l.delinquent]

    @property
    def prefetchable(self) -> list[LoadReport]:
        return [l for l in self.loads if l.prefetchable]

    @property
    def critical_targets(self) -> list[LoadReport]:
        return [l for l in self.loads if l.prefetchable and l.critical]

    def summary(self) -> str:
        lines = [f"loads={len(self.loads)} DILs={len(self.dils)} "
                 f"prefetchable={len(self.prefetchable)} "
                 f"critical={len(self.critical_targets)}"]
        lines += [l.row() for l in self.loads]
        return "\n".join(lines)


def _table_info(fn: ir.FlatFn, atom) -> tuple[tuple, Any, int]:
    if isinstance(atom, ir.Lit):
        arr = np.asarray(atom.val)
        return tuple(arr.shape), arr.dtype, arr.nbytes
    if atom in fn.const_env:
        arr = fn.const_env[atom]
        aval = jax.api_util.shaped_abstractify(arr)
        return tuple(aval.shape), aval.dtype, int(
            math.prod(aval.shape) * aval.dtype.itemsize)
    aval = fn.avals.get(atom)
    if aval is None:
        return (), np.dtype(np.float32), 0
    return tuple(aval.shape), aval.dtype, int(
        math.prod(aval.shape) * np.dtype(aval.dtype).itemsize)


def _index_atoms(op: ir.Op) -> list[Any]:
    if op.name == "gather":
        return [op.invals[1]]
    return list(op.invals[1:])  # dynamic_slice start indices


class _LoopAnalysis:
    """Shared machinery for a single loop body's flat IR."""

    def __init__(self, fn: ir.FlatFn, carry_in_ids, carry_out_atoms,
                 xs_ids, stable_ids):
        self.fn = fn
        self.carry_in_ids = list(carry_in_ids)
        self.carry_out_atoms = list(carry_out_atoms)
        self.xs_ids = set(xs_ids)
        self.stable_ids = set(stable_ids)
        self.carry_pos = {cid: p for p, cid in enumerate(self.carry_in_ids)}

    # -- recurrence-closed backward slice -----------------------------------
    def closed_slice(self, roots: Sequence[int]) -> tuple[list[ir.Op], set[int]]:
        """Backward slice of ``roots``, closed under the recurrences of every
        carry it reads.  Returns (ops, carry_positions_used)."""
        fn = self.fn
        root_ids = [r for r in roots if isinstance(r, int)]
        ops = ir.backward_slice(fn, root_ids)
        used_carries: set[int] = set()
        while True:
            free = ir.slice_free_inputs(fn, ops, root_ids)
            new_carries = {self.carry_pos[f] for f in free
                           if f in self.carry_pos} - used_carries
            if not new_carries:
                return ops, used_carries
            used_carries |= new_carries
            more = ir.backward_slice(fn, root_ids + [
                a for p in used_carries
                if isinstance(self.carry_out_atoms[p], int)
                for a in [self.carry_out_atoms[p]]])
            ops = more

    # -- affinity -----------------------------------------------------------
    def _is_const_atom(self, atom) -> bool:
        return isinstance(atom, ir.Lit) or atom in self.fn.const_env \
            or atom in self.stable_ids

    def slice_is_affine(self, ops: Sequence[ir.Op]) -> bool:
        produced = {o for op in ops for o in op.outs}
        for op in ops:
            if op.name in AFFINE_PRIMS:
                continue
            if op.name in SCALE_PRIMS:
                # affine iff at most one operand is loop-varying
                varying = [a for a in op.invals
                           if isinstance(a, int) and a in produced
                           or (isinstance(a, int) and a in self.carry_pos)]
                if len(varying) <= 1:
                    continue
                return False
            return False
        return True

    # -- cycles -------------------------------------------------------------
    def cycle_ops(self, ops: Sequence[ir.Op]) -> set[int]:
        """Op indices participating in loop-carried cycles within ``ops``."""
        succ = self._slice_graph(ops)
        return nodes_in_cycles(list(succ.keys()), succ)

    def count_simple_cycles(self, ops: Sequence[ir.Op],
                            limit: int = 64) -> int:
        """Johnson-style simple-cycle count for the backward slice — the
        paper's Fig 3b/5 reporting metric (it uses networkx for this)."""
        from .graphs import simple_cycles
        succ = self._slice_graph(ops)
        return sum(1 for _ in simple_cycles(list(succ.keys()), succ,
                                            limit=limit))

    def _slice_graph(self, ops: Sequence[ir.Op]) -> dict[int, list[int]]:
        opset = {op.idx: op for op in ops}
        consumers: dict[int, list[int]] = {}
        for op in ops:
            for a in op.in_ids():
                consumers.setdefault(a, []).append(op.idx)
        succ: dict[int, list[int]] = {op.idx: [] for op in ops}
        for op in ops:
            for o in op.outs:
                succ[op.idx].extend(consumers.get(o, ()))
        for p, cid in enumerate(self.carry_in_ids):
            atom = self.carry_out_atoms[p]
            if not isinstance(atom, int):
                continue
            prod = self.fn.producer.get(atom)
            if prod is not None and prod.idx in opset:
                succ[prod.idx].extend(consumers.get(cid, ()))
        return succ

    # -- classification ------------------------------------------------------
    def classify_index(self, op: ir.Op) -> tuple[str, list[ir.Op], set[int], list]:
        reasons = []
        roots = []
        for atom in _index_atoms(op):
            if isinstance(atom, int) and not self._is_const_atom(atom):
                roots.append(atom)
        if not roots:
            return CONSTANT, [], set(), ["all index operands loop-invariant"]
        ops, carries = self.closed_slice(roots)
        free = ir.slice_free_inputs(self.fn, ops, roots)
        uses_xs = bool(free & self.xs_ids) or any(
            r in self.xs_ids for r in roots)
        has_load = any(o.name in LOAD_PRIMS for o in ops)
        affine = self.slice_is_affine(ops)
        if uses_xs:
            reasons.append("index streamed from loop data (xs)")
        if has_load:
            reasons.append("index produced by another load")
        if not affine:
            bad = [o.name for o in ops
                   if o.name not in AFFINE_PRIMS and o.name not in SCALE_PRIMS]
            reasons.append(f"nonlinear index computation: {sorted(set(bad))[:6]}")
        if not uses_xs and not has_load and affine:
            return STRIDING, ops, carries, ["affine recurrence"]
        return IRREGULAR, ops, carries, reasons

    def control_independent(self, ops: Sequence[ir.Op]) -> tuple[bool, list]:
        """No divergent control flow in the index slice.

        ``select_n`` is *predication*: both arms are computed, so the
        backward slice is identical regardless of the predicate — the
        carrot simply duplicates the whole slice (dependent feeder loads
        included; §2 "prefetching the entire dependency chain").  The
        paper's binary-search-tree exclusion — the next address needs this
        iteration's *loaded* value — surfaces in jaxpr dataflow as a
        loop-carried cycle through the load and is caught by the
        runnable/chasing check.  Genuine control divergence is only
        ``cond``/``while``.
        """
        for op in ops:
            if op.name in ("cond", "while"):
                return False, [f"{op.name} in index slice"]
        return True, []

    def analyze(self, delinquent_bytes: int) -> LoopReport:
        fn = self.fn
        loads: list[LoadReport] = []
        for op in fn.ops:
            if op.name not in LOAD_PRIMS:
                continue
            idx_atoms = _index_atoms(op)
            if all(self._is_const_atom(a) or isinstance(a, ir.Lit)
                   for a in idx_atoms):
                cls, ops, carries, reasons = CONSTANT, [], set(), []
            else:
                cls, ops, carries, reasons = self.classify_index(op)
            shape, dtype, nbytes = _table_info(fn, op.invals[0])
            delinquent = nbytes >= delinquent_bytes
            if cls == IRREGULAR:
                cyc = self.cycle_ops(ops)
                chasing = [i for i in cyc
                           if fn.ops[i].name in LOAD_PRIMS]
                runnable = not chasing
                if chasing:
                    reasons.append(
                        f"chasing: load op(s) {chasing} inside loop-carried cycle")
                ctrl, ctrl_reasons = self.control_independent(ops)
                reasons += ctrl_reasons
                n_cyc = len(chasing)
                n_simple = self.count_simple_cycles(ops)
                if n_simple:
                    reasons.append(f"{n_simple} simple cycle(s) in slice")
            else:
                runnable, ctrl, n_cyc = True, True, 0
            loads.append(LoadReport(
                op_idx=op.idx, prim=op.name, table_shape=shape,
                table_dtype=dtype, table_bytes=nbytes, index_class=cls,
                delinquent=delinquent, runnable=runnable,
                control_independent=ctrl,
                prefetchable=(cls == IRREGULAR and delinquent and runnable
                              and ctrl),
                n_cycles_with_loads=n_cyc, reasons=reasons))
        self._coalesce(loads)
        return LoopReport(fn, self.carry_in_ids, self.carry_out_atoms,
                          sorted(self.xs_ids), self.stable_ids, loads)

    # -- coalescing (same-cache-line rule, §4.1) -----------------------------
    # The paper coalesces loads whose addresses sit a small constant
    # offset apart, via its dynamic traces.  We do the same dynamically:
    # run the loop body concretely for a few iterations on synthesized
    # inputs and group loads whose observed indices differ by a constant
    # within the line window.  (Structural matching cannot see through
    # jnp.take's branchless negative-index wrap; profiling can — and is
    # what the paper actually does.)
    COALESCE_WINDOW = 16
    _COALESCE_ITERS = 4

    def _synth(self, vid):
        aval = self.fn.avals.get(vid)
        rng = np.random.default_rng(vid)
        if aval is None:
            return np.int32(1)
        dt = np.dtype(aval.dtype)
        if np.issubdtype(dt, np.integer):
            return rng.integers(1, 97, size=aval.shape).astype(dt)
        if dt == np.bool_:
            return np.zeros(aval.shape, dt)
        return rng.uniform(0.5, 1.5, size=aval.shape).astype(dt)

    def _profile_indices(self, ops_of_interest) -> dict[int, list[int]] | None:
        fn = self.fn
        try:
            carry = [self._synth(c) for c in self.carry_in_ids]
            trace: dict[int, list[int]] = {o.idx: [] for o in ops_of_interest}
            for it in range(self._COALESCE_ITERS):
                env = dict(zip(self.carry_in_ids, carry))
                for x in self.xs_ids:
                    env[x] = self._synth(x + 1000 * it)
                fn.eval_ops(env, fn.ops)
                for o in ops_of_interest:
                    v = fn._read(env, _index_atoms(o)[0])
                    trace[o.idx].append(int(np.asarray(v).reshape(-1)[0]))
                carry = [np.asarray(fn._read(env, a))
                         for a in self.fn.outvals[:len(carry)]]
            return trace
        except Exception:       # synthesized inputs hit a numeric edge
            return None

    def _coalesce(self, loads: list[LoadReport]) -> None:
        cands = [l for l in loads if l.prefetchable]
        if not cands:
            return
        if len(cands) == 1:
            cands[0].critical = True
            return
        ops = [self.fn.ops[l.op_idx] for l in cands]
        trace = self._profile_indices(ops)
        parent = list(range(len(cands)))

        def find(i):
            while parent[i] != i:
                parent[i] = parent[parent[i]]
                i = parent[i]
            return i

        if trace is not None:
            for i in range(len(cands)):
                for j in range(i + 1, len(cands)):
                    a = np.asarray(trace[cands[i].op_idx])
                    b = np.asarray(trace[cands[j].op_idx])
                    d = b - a
                    if (d == d[0]).all() and abs(int(d[0])) <= \
                            self.COALESCE_WINDOW:
                        parent[find(j)] = find(i)
        groups: dict[int, list[LoadReport]] = {}
        for i, l in enumerate(cands):
            l.group_root = find(i)
            groups.setdefault(find(i), []).append(l)
        for members in groups.values():
            best = max(members, key=lambda l: l.table_bytes)
            best.critical = True
            for m in members:
                if m is not best:
                    m.reasons.append(
                        f"coalesced into critical load op{best.op_idx}")


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FlatLoopBody:
    """A flattened scan body plus the pytree metadata to rebuild it."""
    fn: ir.FlatFn
    carry_tree: Any
    x_tree: Any
    y_tree: Any
    n_carry: int
    n_x: int


def flatten_loop_body(body_fn: Callable, init_carry, x_example) -> FlatLoopBody:
    import jax.tree_util as jtu
    carry_flat, carry_tree = jtu.tree_flatten(init_carry)
    x_flat, x_tree = jtu.tree_flatten(x_example)
    y_tree_box = {}

    def flat_body(*flat):
        c = jtu.tree_unflatten(carry_tree, flat[:len(carry_flat)])
        x = jtu.tree_unflatten(x_tree, flat[len(carry_flat):])
        new_c, y = body_fn(c, x)
        new_c_flat, new_tree = jtu.tree_flatten(new_c)
        assert new_tree == carry_tree, "carry structure must be invariant"
        y_flat, y_tree = jtu.tree_flatten(y)
        y_tree_box["tree"] = y_tree
        return (*new_c_flat, *y_flat)

    closed = jax.make_jaxpr(flat_body)(*carry_flat, *x_flat)
    fn = ir.flatten_closed_jaxpr(closed)
    return FlatLoopBody(fn, carry_tree, x_tree, y_tree_box["tree"],
                        len(carry_flat), len(x_flat))


def screen_body(body: FlatLoopBody, *,
                delinquent_bytes: int = 4 * 2**20) -> LoopReport:
    fn, n_c = body.fn, body.n_carry
    analysis = _LoopAnalysis(
        fn,
        carry_in_ids=fn.invars[:n_c],
        carry_out_atoms=fn.outvals[:n_c],
        xs_ids=fn.invars[n_c:],
        stable_ids=set(),
    )
    return analysis.analyze(delinquent_bytes)


def screen_loop(body_fn: Callable, init_carry, x_example, *,
                delinquent_bytes: int = 4 * 2**20) -> LoopReport:
    """Screen a scan-style ``body_fn(carry, x) -> (carry, y)``."""
    return screen_body(flatten_loop_body(body_fn, init_carry, x_example),
                       delinquent_bytes=delinquent_bytes)


def screen_scan_eqn(closed_body: jcore.ClosedJaxpr, num_consts: int,
                    num_carry: int, *,
                    delinquent_bytes: int = 4 * 2**20) -> LoopReport:
    """Screen the body jaxpr of a traced ``lax.scan`` equation."""
    fn = ir.flatten_closed_jaxpr(closed_body)
    analysis = _LoopAnalysis(
        fn,
        carry_in_ids=fn.invars[num_consts:num_consts + num_carry],
        carry_out_atoms=fn.outvals[:num_carry],
        xs_ids=fn.invars[num_consts + num_carry:],
        stable_ids=set(fn.invars[:num_consts]),
    )
    return analysis.analyze(delinquent_bytes)


def delta_histogram(report: LoopReport, load: LoadReport, init_carry,
                    xs, n_iters: int = 256) -> dict[int, int]:
    """Dynamic address-delta histogram for one load (paper §4.1).

    Runs the loop body concretely for ``n_iters`` iterations, recording the
    load's index operand each iteration, and returns ``{delta: count}``.
    The paper's irregularity rule — at least 10 distinct deltas covering
    the top 90 % of executions — is exposed via :func:`is_irregular_deltas`.
    """
    import jax.tree_util as jtu
    fn = report.flat
    op = fn.ops[load.op_idx]
    idx_atoms = _index_atoms(op)
    carry_vals = [np.asarray(v) for v in jtu.tree_leaves(init_carry)]
    xs_leaves = jtu.tree_leaves(xs)
    n = min(n_iters, xs_leaves[0].shape[0] if xs_leaves else n_iters)
    seen: list[int] = []
    for i in range(n):
        x_vals = [np.asarray(l)[i] for l in xs_leaves]
        env = dict(zip(fn.invars, list(carry_vals) + x_vals))
        fn.eval_ops(env, fn.ops)
        idx_val = np.asarray(fn._read(env, idx_atoms[0])).reshape(-1)[0]
        seen.append(int(idx_val))
        carry_vals = [fn._read(env, a) for a in
                      fn.outvals[:len(carry_vals)]]
    deltas = np.diff(np.asarray(seen))
    hist: dict[int, int] = {}
    for d in deltas:
        hist[int(d)] = hist.get(int(d), 0) + 1
    return hist


def is_irregular_deltas(hist: dict[int, int], min_deltas: int = 10,
                        coverage: float = 0.9) -> bool:
    """Paper rule: >= ``min_deltas`` distinct deltas cover ``coverage``."""
    if not hist:
        return False
    total = sum(hist.values())
    counts = sorted(hist.values(), reverse=True)
    acc, k = 0, 0
    for c in counts:
        acc += c
        k += 1
        if acc >= coverage * total:
            break
    return k >= min_deltas


def screen(f: Callable, *example_args,
           delinquent_bytes: int = 4 * 2**20) -> dict[str, LoopReport]:
    """Screen every ``lax.scan`` loop inside a traced function.

    Analogue of the paper's whole-trace pipeline: find loops, screen each.
    Returns ``{loop_name: LoopReport}`` keyed by ``scan[i]`` position.
    """
    closed = jax.make_jaxpr(f)(*example_args)
    out: dict[str, LoopReport] = {}
    counter = [0]

    def visit(jaxpr: jcore.Jaxpr):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name == "scan":
                body = eqn.params["jaxpr"]
                out[f"scan{counter[0]}"] = screen_scan_eqn(
                    body, eqn.params["num_consts"], eqn.params["num_carry"],
                    delinquent_bytes=delinquent_bytes)
                counter[0] += 1
                visit(body.jaxpr)
            else:
                sub = ir._sub_jaxpr(eqn)
                if sub is not None:
                    visit(sub.jaxpr)
                if name == "cond":
                    for br in eqn.params.get("branches", ()):
                        visit(br.jaxpr)
                if name == "while":
                    visit(eqn.params["body_jaxpr"].jaxpr)
    visit(closed.jaxpr)
    return out
