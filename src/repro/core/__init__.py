"""Core: the paper's contribution — DIL screening + inline prefetch codegen.

Public API:

* :func:`repro.core.dil.screen` / :func:`screen_loop` — the DIL screen
  (§4.1): classify loads in a loop as constant / striding / irregular,
  delinquent, runnable vs chasing, control-(in)dependent, prefetchable.
* :func:`repro.core.pipeline.prefetch_scan` — drop-in ``lax.scan``
  replacement implementing the carrot-and-horse inline prefetcher (§4.2).
* :func:`repro.core.pipeline.pipelined_scan` — the manual split API.
* :func:`repro.core.planner.plan_prefetch_distance` — static ``k``.
"""
from .dil import (LoadReport, LoopReport, screen, screen_loop,  # noqa: F401
                  screen_scan_eqn, delta_histogram, is_irregular_deltas,
                  CONSTANT, STRIDING, IRREGULAR)
from .pipeline import (prefetch_scan, pipelined_scan,  # noqa: F401
                       plan_prefetch, PrefetchPlan)
from .planner import (HardwareModel, V5E, plan_prefetch_distance,  # noqa: F401
                      iter_time, ring_bytes)
