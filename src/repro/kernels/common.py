"""Shared machinery for the inline-prefetch Pallas kernels.

Every kernel in this package is an instance of the paper's
carrot-and-horse schedule transcribed to the TPU memory hierarchy:

* the **carrot** is the index computation running ``lookahead`` grid
  steps ahead, reading the scalar-prefetch operand (SMEM) — legal
  precisely because the DIL screen proved the index stream *runnable*
  (computable without the gathered data);
* the **prefetch** is an explicit async DMA HBM->VMEM into a
  ``lookahead``-deep ring of VMEM slots (the paper's scavenged
  registers);
* the **horse** is the compute consuming ring slot ``g % lookahead``;
* **head start / stay ahead / join** are the ring warm-up at grid step
  0, the steady-state slot recycling, and the tail steps that stop
  issuing DMAs (``g + lookahead >= num_blocks``).

``RowRing`` encapsulates the slot arithmetic so each kernel body stays
readable.  All kernels validate bit-exactly against their ``ref.py``
oracle under ``interpret=True`` (this container is CPU-only; TPU is the
target).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


class RowRing:
    """A k-deep ring of per-row async HBM->VMEM copies.

    ``ring``  VMEM scratch of shape (lookahead, rows_per_block, *row_shape)
    ``sems``  DMA semaphores of shape (lookahead, rows_per_block)
    ``row_for(block, r)`` returns the dynamic source row index.
    """

    def __init__(self, table_ref, ring, sems, row_for, rows_per_block: int,
                 lookahead: int):
        self.table_ref = table_ref
        self.ring = ring
        self.sems = sems
        self.row_for = row_for
        self.rows_per_block = rows_per_block
        self.lookahead = lookahead

    def _copy(self, blk, slot, r):
        row = self.row_for(blk, r)
        return pltpu.make_async_copy(
            self.table_ref.at[pl.ds(row, 1)],
            self.ring.at[slot, pl.ds(r, 1)],
            self.sems.at[slot, r],
        )

    def start_block(self, blk, slot):
        for r in range(self.rows_per_block):
            self._copy(blk, slot, r).start()

    def wait_block(self, blk, slot):
        for r in range(self.rows_per_block):
            self._copy(blk, slot, r).wait()

    def head_start(self, num_blocks):
        """Issue the first ``lookahead`` blocks of DMAs (paper: head start)."""
        @pl.when(pl.program_id(0) == 0)
        def _():
            for j in range(self.lookahead):
                @pl.when(j < num_blocks)
                def _():
                    self.start_block(j, j)

    def steady(self, g, num_blocks):
        """Wait for block ``g``'s rows; pre-issue block ``g + lookahead``.

        Returns the ring slot holding block ``g``.  Call
        ``consume(slot)`` -> compute -> then ``advance``ing is implicit:
        the next DMA into this slot is issued here *after* wait; the
        caller must read the slot before the next grid step overwrites
        it, which Pallas guarantees because grid steps are sequential on
        a TPU core.
        """
        slot = jax.lax.rem(g, jnp.int32(self.lookahead))
        self.wait_block(g, slot)
        return slot

    def stay_ahead(self, g, slot, num_blocks):
        @pl.when(g + self.lookahead < num_blocks)
        def _():
            self.start_block(g + self.lookahead, slot)


def pad_rows(x, multiple: int, axis: int = 0, fill=0):
    """Pad ``x`` along ``axis`` to a multiple; returns (padded, orig_len)."""
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=fill), n
