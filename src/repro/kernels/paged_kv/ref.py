"""Pure-jnp oracle for the paged_kv kernel (decode-path DIL)."""
import jax.numpy as jnp


def paged_attn_scores_ref(pool: jnp.ndarray, page_table: jnp.ndarray,
                          q: jnp.ndarray) -> jnp.ndarray:
    """Attention logits of one query against a paged KV cache.

    ``pool``: (P, page_size, D) physical key pages in HBM.
    ``page_table``: (B, NP) int32 logical->physical page ids.
    ``q``: (B, D) one query vector per sequence (decode step).
    Returns (B, NP, page_size) = q · k over every paged key — the
    serving-side delinquent irregular load (page indirection).
    """
    pages = pool[page_table]                    # (B, NP, page, D)
    return jnp.einsum("bnpd,bd->bnp", pages, q)
