from .ops import paged_attn_scores  # noqa: F401
from .ref import paged_attn_scores_ref  # noqa: F401
