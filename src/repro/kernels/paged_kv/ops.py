"""Jitted wrapper for paged_attn_scores."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import common
from . import kernel as _k
from .ref import paged_attn_scores_ref


@functools.partial(jax.jit, static_argnames=("lookahead", "interpret"))
def paged_attn_scores(pool: jnp.ndarray, page_table: jnp.ndarray,
                      q: jnp.ndarray, *, lookahead: int = 4,
                      interpret: bool | None = None) -> jnp.ndarray:
    """q·K over a paged KV cache; see ref.py for shapes."""
    if interpret is None:
        interpret = common.on_cpu()
    B, NP = page_table.shape
    fn = _k.build(B, NP, pool.shape, pool.dtype, lookahead=lookahead,
                  interpret=interpret)
    out = fn(page_table.astype(jnp.int32).reshape(-1), pool, q)
    return out.reshape(B, NP, pool.shape[1])


__all__ = ["paged_attn_scores", "paged_attn_scores_ref"]
