"""Inline-prefetch paged-KV attention scores (decode path).

Serving with a paged KV cache turns every decode step into the paper's
DIL pattern: the physical page address is ``pool[page_table[b, p]]`` — an
indirection through a dynamically-grown table.  The page-id stream is
*runnable* (it comes from the allocator, not from the KV data), so the
carrot DMAs page ``g + k`` while the MXU computes q·K on page ``g``.

Grid is the flattened (batch, logical-page) space; the query row for the
current sequence arrives through the regular BlockSpec pipeline (it is a
striding operand — left to the "hardware" pipeline, exactly like the
paper leaves striding loads to the CPU's prefetchers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(ptab_ref, pool_ref, q_ref, out_ref, ring, sems, *,
            lookahead: int):
    g = pl.program_id(0)
    nb = pl.num_programs(0)

    def copy(step, slot):
        page = ptab_ref[step]
        return pltpu.make_async_copy(
            pool_ref.at[pl.ds(page, 1)],       # (1, page_size, D)
            ring.at[pl.ds(slot, 1)],
            sems.at[slot],
        )

    @pl.when(g == 0)
    def _():                                    # head start
        for j in range(lookahead):
            @pl.when(j < nb)
            def _():
                copy(j, j).start()

    slot = jax.lax.rem(g, jnp.int32(lookahead))
    copy(g, slot).wait()

    keys = ring[slot]                           # (page_size, D)
    q = q_ref[0]                                # (D,)
    out_ref[...] = (keys @ q)[None, :]          # (1, page_size)

    @pl.when(g + lookahead < nb)
    def _():                                    # stay ahead / join
        copy(g + lookahead, slot).start()


def build(batch: int, n_pages: int, pool_shape: tuple, dtype, *,
          lookahead: int, interpret: bool):
    P, page_size, D = pool_shape
    nb = batch * n_pages
    lookahead = max(1, min(lookahead, nb))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),              # pool in HBM
            pl.BlockSpec((1, D), lambda g, ptab: (g // n_pages, 0)),  # q row
        ],
        out_specs=pl.BlockSpec((1, page_size), lambda g, ptab: (g, 0)),
        scratch_shapes=[
            pltpu.VMEM((lookahead, page_size, D), dtype),
            pltpu.SemaphoreType.DMA((lookahead,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, lookahead=lookahead),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, page_size), dtype),
        interpret=interpret,
    )
