"""Inline-prefetch CSR neighbor gather + mean (PageRank/Graph500 analogue).

One grid step owns one node; its ``max_deg`` neighbor rows are DMA'd by
the carrot ``lookahead`` nodes ahead (the neighbor-id stream lives in
SMEM via scalar prefetch — CSR adjacency is data, not a function of the
gathered features, so the slice is runnable).  Padding ids (< 0) are
clamped to row 0 for the DMA and masked out of the reduction — the DMA
still moves a line, mirroring the paper's observation that prefetching
must be *safe* on the join/overrun path rather than skipped.

The horse reduces the ``(max_deg, D)`` ring slot to a mean row while the
next node's rows are in flight — compute/DMA overlap on the op the
hardware pipeline cannot block-schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from ..common import RowRing


def _kernel(nbrs_ref, feats_ref, out_ref, ring, sems, *, max_deg: int,
            lookahead: int):
    g = pl.program_id(0)
    nb = pl.num_programs(0)

    def row_for(node, r):
        nid = nbrs_ref[node * max_deg + r]
        return jnp.maximum(nid, 0)          # clamp padding for a safe DMA

    rr = RowRing(feats_ref, ring, sems, row_for=row_for,
                 rows_per_block=max_deg, lookahead=lookahead)
    rr.head_start(nb)
    slot = rr.steady(g, nb)

    ids = jnp.stack([nbrs_ref[g * max_deg + r] for r in range(max_deg)])
    mask = (ids >= 0).astype(ring.dtype)                     # (M,)
    rows = ring[slot] * mask[:, None]                        # (M, D)
    deg = jnp.maximum(mask.sum(), 1).astype(ring.dtype)
    out_ref[...] = (rows.sum(axis=0) / deg)[None, :]

    rr.stay_ahead(g, slot, nb)


def build(n_nodes: int, feats_shape: tuple, dtype, *, max_deg: int,
          lookahead: int, interpret: bool):
    D = feats_shape[1]
    lookahead = max(1, min(lookahead, n_nodes))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_nodes,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((1, D), lambda g, nbrs_ref: (g, 0)),
        scratch_shapes=[
            pltpu.VMEM((lookahead, max_deg, D), dtype),
            pltpu.SemaphoreType.DMA((lookahead, max_deg)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, max_deg=max_deg, lookahead=lookahead),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_nodes, D), dtype),
        interpret=interpret,
    )
