"""Jitted wrapper for csr_gather_mean."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import common
from . import kernel as _k
from .ref import csr_gather_mean_ref


@functools.partial(jax.jit, static_argnames=("lookahead", "interpret"))
def csr_gather_mean(feats: jnp.ndarray, nbrs: jnp.ndarray, *,
                    lookahead: int = 8,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Mean of neighbor rows: ``feats`` (R, D), ``nbrs`` (N, M) with -1 pad."""
    if interpret is None:
        interpret = common.on_cpu()
    n, max_deg = nbrs.shape
    fn = _k.build(n, feats.shape, feats.dtype, max_deg=max_deg,
                  lookahead=lookahead, interpret=interpret)
    return fn(nbrs.astype(jnp.int32).reshape(-1), feats)


__all__ = ["csr_gather_mean", "csr_gather_mean_ref"]
