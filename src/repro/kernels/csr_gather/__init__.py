from .ops import csr_gather_mean  # noqa: F401
from .ref import csr_gather_mean_ref  # noqa: F401
