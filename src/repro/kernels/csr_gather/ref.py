"""Pure-jnp oracle for the csr_gather kernel (PageRank / BFS analogue)."""
import jax.numpy as jnp


def csr_gather_mean_ref(feats: jnp.ndarray,
                        nbrs: jnp.ndarray) -> jnp.ndarray:
    """Mean of neighbor feature rows.

    ``feats``: (R, D) node features.  ``nbrs``: (N, M) padded neighbor
    ids, ``-1`` = padding.  Returns (N, D) — the PageRank inner loop
    (sum of incoming ranks) with irregular neighbor-row gathers.
    """
    mask = (nbrs >= 0)
    safe = jnp.where(mask, nbrs, 0)
    rows = feats[safe]                             # (N, M, D)
    rows = rows * mask[..., None].astype(feats.dtype)
    deg = jnp.maximum(mask.sum(axis=1), 1).astype(feats.dtype)
    return rows.sum(axis=1) / deg[:, None]
