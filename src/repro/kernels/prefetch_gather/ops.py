"""Jitted public wrapper for the prefetch_gather kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import common
from . import kernel as _k
from .ref import prefetch_gather_ref


@functools.partial(jax.jit, static_argnames=("block_rows", "lookahead",
                                             "interpret"))
def prefetch_gather(table: jnp.ndarray, idx: jnp.ndarray, *,
                    block_rows: int = 8, lookahead: int = 8,
                    interpret: bool | None = None) -> jnp.ndarray:
    """``table[idx]`` with a k-deep inline-prefetch pipeline.

    ``table``: (R, ...) source in HBM.  ``idx``: (N,) int32 row ids.
    ``lookahead`` is the paper's prefetch distance k (in blocks).
    Falls back to interpret mode automatically off-TPU.
    """
    if interpret is None:
        interpret = common.on_cpu()
    if idx.dtype != jnp.int32:
        idx = idx.astype(jnp.int32)
    # clamp (paper: join-phase overrun safety; also matches ref mode="clip")
    idx = jnp.clip(idx, 0, table.shape[0] - 1)
    idx_p, n = common.pad_rows(idx, block_rows)
    fn = _k.build(idx_p.shape[0], table.shape, table.dtype,
                  block_rows=block_rows, lookahead=lookahead,
                  interpret=interpret)
    out = fn(idx_p, table)
    return out[:n]


__all__ = ["prefetch_gather", "prefetch_gather_ref"]
