"""Inline-prefetch irregular row gather — the flagship kernel.

``out[i] = table[idx[i]]`` with ``table`` resident in HBM (memory space
ANY, *not* block-pipelined by Pallas) and ``idx`` a scalar-prefetch
operand in SMEM.  The index stream is the paper's *runnable backward
slice*: it is available ahead of time precisely because the DIL screen
proved it independent of the gathered data.

Schedule (paper Fig. 6):

* grid step ``g`` owns a block of ``block_rows`` output rows;
* at ``g == 0`` the kernel issues DMAs for blocks ``0 .. k-1``
  (**head start**);
* every step waits for block ``g``'s rows in ring slot ``g % k``,
  copies them to the output block (**horse**), then issues block
  ``g + k``'s DMAs into the now-free slot (**stay ahead**, carrot);
* the last ``k`` blocks issue nothing (**join**).

VMEM footprint: ``k * block_rows * row_bytes`` ring + one output block —
chosen by :func:`repro.core.planner.plan_prefetch_distance`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from ..common import RowRing


def _kernel(idx_ref, table_ref, out_ref, ring, sems, *, block_rows: int,
            lookahead: int):
    g = pl.program_id(0)
    nb = pl.num_programs(0)
    rr = RowRing(table_ref, ring, sems,
                 row_for=lambda blk, r: idx_ref[blk * block_rows + r],
                 rows_per_block=block_rows, lookahead=lookahead)
    rr.head_start(nb)
    slot = rr.steady(g, nb)
    out_ref[...] = ring[slot]
    rr.stay_ahead(g, slot, nb)


def build(n_rows: int, table_shape: tuple, dtype, *, block_rows: int,
          lookahead: int, interpret: bool):
    """Construct the pallas_call for a padded problem size."""
    assert n_rows % block_rows == 0
    nb = n_rows // block_rows
    lookahead = max(1, min(lookahead, nb))
    feat = table_shape[1:]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],   # table stays in HBM
        out_specs=pl.BlockSpec((block_rows,) + feat,
                               lambda g, idx_ref: (g,) + (0,) * len(feat)),
        scratch_shapes=[
            pltpu.VMEM((lookahead, block_rows) + feat, dtype),
            pltpu.SemaphoreType.DMA((lookahead, block_rows)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block_rows=block_rows,
                          lookahead=lookahead),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows,) + feat, dtype),
        interpret=interpret,
    )
