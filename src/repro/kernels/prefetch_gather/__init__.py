from .ops import prefetch_gather  # noqa: F401
from .ref import prefetch_gather_ref  # noqa: F401
