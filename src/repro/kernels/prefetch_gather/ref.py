"""Pure-jnp oracle for the prefetch_gather kernel."""
import jax.numpy as jnp


def prefetch_gather_ref(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """``out[i] = table[idx[i]]`` — XLA dynamic gather, no software pipeline.

    This is both the correctness oracle and the *baseline* the paper
    compares against (the unmodified binary).
    """
    return jnp.take(table, idx, axis=0, mode="clip")
