"""Pure-jnp oracle for the hash_probe kernel (open addressing, bounded
linear probe window — the STLHistogram / HashJoin access pattern).

Table layout: (S, L) int32, col 0 = key (-1 = empty slot), col 1 = value,
cols 2..L-1 = payload padding to a TPU-friendly line width.  Keys in the
table are unique (hash-table semantics), so "the matching slot's value"
is well-defined via a max-reduction over the probe window.
"""
import jax.numpy as jnp

HASH_MULT = 40503  # Knuth-style multiplicative hash constant (fits int32)
_MISS = -(2**31) + 1  # python int: kernels must not capture jax constants


def bucket_of(keys: jnp.ndarray, n_slots: int, window: int) -> jnp.ndarray:
    h = (keys.astype(jnp.uint32) * jnp.uint32(HASH_MULT))
    return (h % jnp.uint32(max(1, n_slots - window))).astype(jnp.int32)


def hash_probe_ref(table: jnp.ndarray, keys: jnp.ndarray,
                   window: int = 8) -> jnp.ndarray:
    """Returns (N, 2) int32: col 0 = value (or -1), col 1 = found flag."""
    S = table.shape[0]
    start = bucket_of(keys, S, window)                      # (N,)
    offs = jnp.arange(window, dtype=jnp.int32)              # (W,)
    slots = start[:, None] + offs[None, :]                  # (N, W)
    wkeys = table[slots, 0]                                 # (N, W)
    wvals = table[slots, 1]
    hit = wkeys == keys[:, None]
    found = hit.any(axis=1)
    vals = jnp.where(found,
                     jnp.max(jnp.where(hit, wvals, jnp.int32(_MISS)), axis=1),
                     jnp.int32(-1))
    return jnp.stack([vals, found.astype(jnp.int32)], axis=1)
