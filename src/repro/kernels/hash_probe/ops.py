"""Jitted wrapper + host-side table builder for hash_probe."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .. import common
from . import kernel as _k
from .ref import HASH_MULT, bucket_of, hash_probe_ref


def build_table(keys: np.ndarray, values: np.ndarray, n_slots: int,
                window: int = 8, line_width: int = 8) -> np.ndarray:
    """Host-side open-addressing insert with bounded linear probing.

    Keys that cannot be placed within ``window`` slots of their bucket
    are dropped (bounded-displacement tables guarantee lookups touch one
    line).  Returns (n_slots, line_width) int32; col0 key, col1 value.
    """
    table = np.full((n_slots, line_width), -1, dtype=np.int32)
    start = np.asarray(bucket_of(jnp.asarray(keys), n_slots, window))
    for k, v, s in zip(keys.tolist(), values.tolist(), start.tolist()):
        for off in range(window):
            slot = s + off
            if table[slot, 0] == -1 or table[slot, 0] == k:
                table[slot, 0] = k
                table[slot, 1] = v
                break
    return table


@functools.partial(jax.jit, static_argnames=("window", "block", "lookahead",
                                             "interpret"))
def hash_probe(table: jnp.ndarray, keys: jnp.ndarray, *, window: int = 8,
               block: int = 8, lookahead: int = 8,
               interpret: bool | None = None) -> jnp.ndarray:
    """Probe (S, L) table for each key. Returns (N, 2): value, found."""
    if interpret is None:
        interpret = common.on_cpu()
    keys = keys.astype(jnp.int32)
    keys_p, n = common.pad_rows(keys, block)
    fn = _k.build(keys_p.shape[0], table.shape, block=block, window=window,
                  lookahead=lookahead, interpret=interpret)
    return fn(keys_p, table)[:n]


__all__ = ["hash_probe", "hash_probe_ref", "build_table", "HASH_MULT"]
