from .ops import hash_probe, build_table, HASH_MULT  # noqa: F401
from .ref import hash_probe_ref  # noqa: F401
