"""Inline-prefetch open-addressing hash probe.

The paper's motivating example (Listing 1) is a hash-table lookup whose
critical DIL is the bucket load: the address is a *hash* of a streamed
key — irregular by construction, runnable because the key stream does
not depend on the loaded buckets.

Here the **carrot is the hash function itself**, duplicated into the
kernel and evaluated on SMEM scalars ``lookahead`` blocks ahead of the
compute; the DMA fetches the ``window``-slot probe line.  The horse then
does the key-compare/select entirely in VMEM — by the time block ``g``
is compared, its buckets arrived ``k`` steps ago.

Table layout (S, L) int32: col 0 key, col 1 value, L padded to the lane
width so one probe window is a well-formed (window, L) VMEM tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .ref import HASH_MULT, _MISS


def _bucket(key, n_slots: int, window: int):
    h = key.astype(jnp.uint32) * jnp.uint32(HASH_MULT)
    return (h % jnp.uint32(max(1, n_slots - window))).astype(jnp.int32)


def _kernel(keys_ref, table_ref, out_ref, ring, sems, *, block: int,
            window: int, lookahead: int, n_slots: int):
    g = pl.program_id(0)
    nb = pl.num_programs(0)

    def copy(blk, slot, b):
        # carrot: recompute the hash of key (blk*block + b) — duplicated
        # backward slice, running ahead of the horse.
        start = _bucket(keys_ref[blk * block + b], n_slots, window)
        return pltpu.make_async_copy(
            table_ref.at[pl.ds(start, window)],
            ring.at[slot, b],
            sems.at[slot, b],
        )

    def start_block(blk, slot):
        for b in range(block):
            copy(blk, slot, b).start()

    def wait_block(blk, slot):
        for b in range(block):
            copy(blk, slot, b).wait()

    @pl.when(g == 0)
    def _():                                   # head start
        for j in range(lookahead):
            @pl.when(j < nb)
            def _():
                start_block(j, j)

    slot = jax.lax.rem(g, jnp.int32(lookahead))
    wait_block(g, slot)                        # stay ahead: value arrived k ago

    keys_vec = jnp.stack(
        [keys_ref[g * block + b] for b in range(block)])       # (B,)
    win = ring[slot]                                           # (B, W, L)
    wkeys, wvals = win[:, :, 0], win[:, :, 1]
    hit = wkeys == keys_vec[:, None]
    found = hit.any(axis=1)
    vals = jnp.where(found,
                     jnp.max(jnp.where(hit, wvals, jnp.int32(_MISS)), axis=1),
                     jnp.int32(-1))
    out_ref[...] = jnp.stack([vals, found.astype(jnp.int32)], axis=1)

    @pl.when(g + lookahead < nb)
    def _():                                   # join: no issue in last k
        start_block(g + lookahead, slot)


def build(n_keys: int, table_shape: tuple, *, block: int, window: int,
          lookahead: int, interpret: bool):
    assert n_keys % block == 0
    nb = n_keys // block
    lookahead = max(1, min(lookahead, nb))
    S, L = table_shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((block, 2), lambda g, keys_ref: (g, 0)),
        scratch_shapes=[
            pltpu.VMEM((lookahead, block, window, L), jnp.int32),
            pltpu.SemaphoreType.DMA((lookahead, block)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, block=block, window=window,
                          lookahead=lookahead, n_slots=S),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_keys, 2), jnp.int32),
        interpret=interpret,
    )
