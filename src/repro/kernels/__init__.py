"""Pallas TPU kernels implementing the paper's inline-prefetch schedule
for the four DIL sites of the framework:

* ``prefetch_gather``   — irregular row gather (embedding / MoE dispatch)
* ``hash_probe``        — open-addressing probe (STLHistogram / HashJoin)
* ``csr_gather``        — neighbor gather + mean (PageRank / Graph500)
* ``paged_kv``          — paged-KV attention scores (decode serving)

Each subpackage is ``kernel.py`` (pl.pallas_call + BlockSpec/DMA ring),
``ops.py`` (jitted wrapper) and ``ref.py`` (pure-jnp oracle).  Kernels
are validated bit-exactly in interpret mode on CPU; TPU v5e is the
compile target.
"""
from .prefetch_gather import prefetch_gather, prefetch_gather_ref  # noqa: F401
from .hash_probe import hash_probe, hash_probe_ref, build_table  # noqa: F401
from .csr_gather import csr_gather_mean, csr_gather_mean_ref  # noqa: F401
from .paged_kv import paged_attn_scores, paged_attn_scores_ref  # noqa: F401
