"""ShapeDtypeStruct stand-ins + step functions for every dry-run cell.

``input_specs(arch, shape)`` returns (step_fn, arg_specs, in_pspecs,
out_pspecs) — weak-type-correct, shardable, zero device allocation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import models
from ..configs import SHAPES, ShapeSpec, get_arch
from ..data import make_batch_specs
from ..optim import AdamWConfig
from ..parallel import batch_pspec, cache_pspecs, data_axes_of, param_pspecs
from ..runtime import TrainConfig, make_train_step


def _abstract(fn, *a, **kw):
    return jax.eval_shape(functools.partial(fn, *a, **kw))


def _spec_tree(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def abstract_opt_state(params_spec):
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {"m": jax.tree.map(f32, params_spec),
            "v": jax.tree.map(f32, params_spec),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _batch_for(cfg, shape: ShapeSpec):
    return make_batch_specs(cfg, shape.seq_len, shape.global_batch)


def input_specs(arch: str, shape_name: str, mesh, *,
                tcfg: TrainConfig | None = None,
                opts: frozenset = frozenset()):
    """Build (step_fn, arg specs, in_pspecs, out_pspecs, donate_argnums).

    ``opts`` — §Perf levers (absent = paper-faithful baseline):
      "triangle"      skip masked causal tiles in flash forward
      "dots_remat"    selective remat (save matmul outputs)
      "grad_compress" bf16 gradient all-reduce with error feedback
      "tp_serve"      model-axis-only weights for inference shapes
    """
    import dataclasses
    cfg = get_arch(arch)
    if "triangle" in opts:
        cfg = dataclasses.replace(cfg, flash_triangle=True)
    if "dots_remat" in opts:
        cfg = dataclasses.replace(cfg, remat_policy="dots")
    if "kv_quant" in opts:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    shape = SHAPES[shape_name]
    params_spec = models.abstract_params(cfg)
    param_mode = "serve" if (shape.kind != "train"
                             and "tp_serve" in opts) else "train"
    p_ps = param_pspecs(params_spec, mesh, mode=param_mode)
    data = data_axes_of(mesh)

    if shape.kind == "train":
        # Microbatch count scales with model size so per-device activation
        # memory stays bounded (grad-accumulation scan).  Baseline keeps
        # gradient compression OFF (paper-faithful); §Perf turns it on.
        n = cfg.param_count()
        mb = 16 if n > 50e9 else (4 if n > 10e9 else 2)
        # each microbatch must still shard over every data axis
        data_size = 1
        for a in mesh.axis_names:
            if a != "model":
                data_size *= mesh.shape[a]
        mb = min(mb, max(1, shape.global_batch // data_size))
        tcfg = tcfg or TrainConfig(
            grad_compression="grad_compress" in opts, microbatches=mb,
            gathered_weights="gathered_weights" in opts)
        step_fn = make_train_step(cfg, tcfg)
        batch = _batch_for(cfg, shape)
        opt_spec = abstract_opt_state(params_spec)
        # EF residual exists only when compression is on (it is a full
        # f32 param-sized tree — 1.6 GB/device at 104B otherwise wasted)
        if tcfg.grad_compression:
            resid_spec = jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                params_spec)
            resid_ps = p_ps
        else:
            resid_spec, resid_ps = {}, {}
        opt_ps = {"m": p_ps, "v": p_ps, "step": P()}
        args = (params_spec, opt_spec, resid_spec, batch,
                jax.ShapeDtypeStruct((), jnp.int32))
        in_ps = (p_ps, opt_ps, resid_ps, batch_pspec(batch, mesh), P())
        out_ps = (p_ps, opt_ps, resid_ps, None)
        # donate params/opt/residual: the step consumes and replaces them
        return step_fn, args, in_ps, out_ps, (0, 1, 2)

    if shape.kind == "prefill":
        batch = _batch_for(cfg, shape)
        batch.pop("labels", None)

        def prefill_fn(params, batch):
            logits, cache = models.prefill(params, batch, cfg,
                                           capacity=shape.seq_len)
            return logits, cache

        args = (params_spec, batch)
        in_ps = (p_ps, batch_pspec(batch, mesh))
        cache_spec = jax.eval_shape(prefill_fn, params_spec, batch)[1]
        out_ps = (P(data, None) if shape.global_batch > 1 else None,
                  cache_pspecs(cache_spec, mesh))
        return prefill_fn, args, in_ps, out_ps, ()

    # decode: one new token against a cache of seq_len
    cache_spec = _abstract(models.init_cache, cfg, shape.global_batch,
                           shape.seq_len)
    tok_spec = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)

    def serve_fn(params, cache, token):
        return models.decode_step(params, cache, token, cfg,
                                  pos=jnp.int32(shape.seq_len - 1))

    args = (params_spec, cache_spec, tok_spec)
    c_ps = cache_pspecs(cache_spec, mesh)
    in_ps = (p_ps, c_ps, batch_pspec(tok_spec, mesh))
    out_ps = (None, c_ps)
    return serve_fn, args, in_ps, out_ps, (1,)   # donate the KV cache
