"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b \
        --steps 100 --batch 8 --seq 256 [--model-axis 1] [--reduced]

On this CPU container ``--reduced`` (default) trains the smoke-scale
variant; on a real TPU slice the same entry point builds the full config
and the (data, model) mesh from the actual device fleet.
"""
from __future__ import annotations

import argparse

from ..configs import get_arch, reduced
from ..optim import AdamWConfig
from ..runtime import TrainConfig, Trainer
from .mesh import make_local_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (TPU-scale)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    mesh = make_local_mesh(model=args.model_axis)
    tr = Trainer(cfg, TrainConfig(microbatches=args.microbatches,
                                  peak_lr=args.lr,
                                  adamw=AdamWConfig(lr=args.lr)),
                 mesh, seq_len=args.seq, global_batch=args.batch,
                 ckpt_dir=args.ckpt)
    hist = tr.run(args.steps, log_every=5)
    for step, loss, dt in hist:
        print(f"step {step:>5}  loss {loss:.4f}  {dt * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
