import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the
device count at first init).  This module is the only place that forces
512 host-platform devices — smoke tests and benches see the real host.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k --mesh single --out results/dryrun
Outputs a JSON record per cell: memory analysis, cost analysis,
per-collective byte counts (parsed from the optimized HLO), and timing.
"""
import argparse
import json
import re
import time
import traceback

import jax

from ..configs import SHAPES, cell_supported, get_arch
from .mesh import make_production_mesh
from .specs import input_specs

# dtype byte widths for HLO operand parsing
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")


def _op_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-operand bytes of every collective op in the HLO."""
    out: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # "name = TYPE[dims] collective-kind(...)"
        m = re.match(r"[%\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", s)
        if not m:
            continue
        kind = m.group(2)
        for c in _COLLECTIVES:
            if kind == c or kind.startswith(c + "-"):
                out[c] += _op_bytes(m.group(1))
                out["count"] += 1
                break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opts: frozenset = frozenset()) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "opts": sorted(opts)}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        step_fn, args, in_ps, out_ps, donate = input_specs(
            arch, shape_name, mesh, opts=opts)
        from ..parallel import activation_sharding
        in_sh = _to_shardings(in_ps, mesh)
        out_sh = _to_shardings(out_ps, mesh)
        with mesh, activation_sharding(mesh):
            jitted = jax.jit(step_fn, in_shardings=in_sh,
                             out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t1
            # collectives live in the *partitioned* HLO (SPMD runs at
            # compile time), so parse compiled.as_text()
            coll = collective_bytes(compiled.as_text())
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
        rec.update(
            status="ok",
            n_devices=mesh.devices.size,
            flops=float(cost.get("flops", -1.0)),
            bytes_accessed=float(cost.get("bytes accessed", -1.0)),
            collectives=coll,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory=_mem_dict(mem),
            model_params=cfg.param_count(),
            model_params_active=cfg.param_count(active_only=True),
        )
        print(f"[dryrun] {arch} {shape_name} "
              f"{'multi' if multi_pod else 'single'}: OK "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
        print("  memory_analysis:", rec["memory"])
        print("  cost_analysis: flops=%.3e bytes=%.3e" %
              (rec["flops"], rec["bytes_accessed"]))
        print("  collective bytes:", coll["total"])
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        print(f"[dryrun] {arch} {shape_name} FAILED: {e}")
    return rec


def _to_shardings(tree, mesh):
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree, is_leaf=lambda x: isinstance(x, PartitionSpec))


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--opts", default="",
                    help="comma list: triangle,dots_remat,grad_compress,"
                         "tp_serve (perf-iteration variants)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    opts = frozenset(o for o in args.opts.split(",") if o)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for multi in meshes:
        rec = run_cell(args.arch, args.shape, multi, opts)
        tag = "multi" if multi else "single"
        if opts:
            tag += "__" + "-".join(sorted(opts))
        path = os.path.join(args.out,
                            f"{args.arch}__{args.shape}__{tag}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print("wrote", path)


if __name__ == "__main__":
    main()
