"""Run the dry-run over many (arch × shape × mesh) cells, resumably.

Each cell runs in a subprocess (jax device-count isolation) and is
skipped if its JSON already records status ok/skipped.  Partitioning via
--part i/n lets several sweep processes run concurrently.
"""
import argparse
import json
import os
import subprocess
import sys

from ..configs import ARCHS, SHAPES


def done(path: str) -> bool:
    if not os.path.exists(path):
        return False
    try:
        with open(path) as f:
            return json.load(f).get("status") in ("ok", "skipped")
    except Exception:
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--part", default="0/1")    # i/n round-robin split
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    i, n = map(int, args.part.split("/"))

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    cells = [(a, s, m) for a in ARCHS for s in SHAPES for m in meshes]
    cells = [c for j, c in enumerate(cells) if j % n == i]
    os.makedirs(args.out, exist_ok=True)

    for arch, shape, mesh in cells:
        path = os.path.join(args.out, f"{arch}__{shape}__{mesh}.json")
        if not args.force and done(path):
            print(f"[sweep] skip {arch} {shape} {mesh} (done)")
            continue
        print(f"[sweep] run  {arch} {shape} {mesh}", flush=True)
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh, "--out", args.out]
        try:
            subprocess.run(cmd, timeout=args.timeout, check=False)
        except subprocess.TimeoutExpired:
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "error",
                           "error": f"compile timeout {args.timeout}s"}, f)
            print(f"[sweep] TIMEOUT {arch} {shape} {mesh}")


if __name__ == "__main__":
    main()
