"""Production mesh construction.

Functions, not module-level constants: importing this module never
touches jax device state (smoke tests and benches must see 1 device;
only the dry-run forces 512 host-platform devices).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(model: int = 1):
    """Whatever this host has, as (data, model) — tests/examples."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
