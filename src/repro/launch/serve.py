"""Serving launcher: prefill + greedy decode for a batch of prompts.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
        --batch 4 --prompt-len 16 --new-tokens 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from .. import models
from ..configs import get_arch, reduced
from ..serving import greedy_generate
from .mesh import make_local_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    mesh = make_local_mesh()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    extra = {}
    if cfg.family == "vlm":
        import jax.numpy as jnp
        extra["patches"] = jnp.zeros(
            (args.batch, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        import jax.numpy as jnp
        extra["frames"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    with mesh:
        toks = greedy_generate(cfg, params, prompts, args.new_tokens,
                               extra=extra or None)
    print(np.asarray(toks))


if __name__ == "__main__":
    main()
