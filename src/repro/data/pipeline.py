"""Deterministic synthetic LM data pipeline, host-sharded.

Every batch is a pure function of ``(seed, step)`` via threefry, so:

* any host can regenerate any shard (no data redistribution on elastic
  re-mesh — host ``h`` of ``H`` serves rows ``h::H``),
* checkpoint/restart resumes mid-stream exactly (the pipeline state *is*
  the step counter),
* straggler re-assignment is a pure re-index.

The token stream is Zipf-distributed over the vocab — matching the
skewed key distributions of the paper's workloads (a uniform stream
would understate hash/bucket collisions in the histogram benchmarks).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.1
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def batch(self, step: int) -> dict:
        """Host-local shard of batch ``step`` (tokens + next-token labels)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        key = jax.random.fold_in(key, self.host_id)
        u = jax.random.uniform(key, (self.host_batch, self.seq_len + 1),
                               minval=1e-6, maxval=1.0)
        # inverse-CDF Zipf-ish: heavy head, long tail
        ranks = jnp.floor(self.vocab_size ** u) - 1
        tokens = jnp.clip(ranks.astype(jnp.int32), 0, self.vocab_size - 1)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def state(self, step: int) -> dict:
        return {"seed": self.seed, "step": step}

    @classmethod
    def from_state(cls, state: dict, **kw) -> tuple["SyntheticLM", int]:
        return cls(seed=state["seed"], **kw), state["step"]


def make_batch_specs(cfg, seq_len: int, global_batch: int):
    """ShapeDtypeStructs for one training batch (dry-run input stand-ins)."""
    f = jnp.float32
    specs = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    if getattr(cfg, "n_patches", 0):
        specs["tokens"] = jax.ShapeDtypeStruct(
            (global_batch, seq_len - cfg.n_patches), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct(
            (global_batch, seq_len - cfg.n_patches), jnp.int32)
        specs["patches"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.n_patches, cfg.d_model), f)
    if getattr(cfg, "family", "") == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.encoder_seq, cfg.d_model), f)
    return specs
