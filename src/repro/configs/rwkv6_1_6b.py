"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536;
Finch, data-dependent decay.  [arXiv:2404.05892; unverified]

Channel-mix uses the two-matrix (gelu) MLP so the parameter count lands
at ~1.6B as published (RWKV's relu² channel mix is two matrices).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab_size=65536,
    mixer_pattern=("rwkv6",), rwkv_head_dim=64, act="gelu",
)
