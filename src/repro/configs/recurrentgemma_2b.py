"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1, MQA)
d_ff=7680 vocab=256000; RG-LRU + local attn, 1:2 ratio (two recurrent
blocks per local-attention block), window 2048.  [arXiv:2402.19427; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_head=256,
    d_ff=7680, vocab_size=256000,
    mixer_pattern=("rglru", "rglru", "attn"), sliding_window=2048,
    rglru_d_rnn=2560, rglru_conv_width=4, act="swiglu",
    tie_embeddings=True,
)
