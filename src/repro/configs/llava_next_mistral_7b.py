"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000; anyres tiling (frontend STUBBED: input_specs()
provides precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=32000,
    n_patches=576, rope_theta=1e6, act="swiglu",
)
