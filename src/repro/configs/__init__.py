"""Architecture registry: ``--arch <id>`` resolution + assigned shapes."""
from __future__ import annotations

import dataclasses

from ..models.config import ModelConfig, reduced  # noqa: F401
from . import (command_r_plus_104b, dbrx_132b, deepseek_moe_16b,
               h2o_danube3_4b, llava_next_mistral_7b, phi4_mini_3_8b,
               qwen3_8b, recurrentgemma_2b, rwkv6_1_6b, whisper_large_v3)

ARCHS: dict[str, ModelConfig] = {
    "qwen3-8b": qwen3_8b.CONFIG,
    "phi4-mini-3.8b": phi4_mini_3_8b.CONFIG,
    "h2o-danube-3-4b": h2o_danube3_4b.CONFIG,
    "command-r-plus-104b": command_r_plus_104b.CONFIG,
    "rwkv6-1.6b": rwkv6_1_6b.CONFIG,
    "llava-next-mistral-7b": llava_next_mistral_7b.CONFIG,
    "whisper-large-v3": whisper_large_v3.CONFIG,
    "deepseek-moe-16b": deepseek_moe_16b.CONFIG,
    "dbrx-132b": dbrx_132b.CONFIG,
    "recurrentgemma-2b": recurrentgemma_2b.CONFIG,
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {list(ARCHS)}")
    return ARCHS[name]


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skipped: pure full-attention arch; long_500k "
                       "requires sub-quadratic attention (DESIGN.md)")
    return True, ""


def all_cells():
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            yield arch, cfg, shape
