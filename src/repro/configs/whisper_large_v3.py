"""whisper-large-v3 [audio] — 32L d_model=1280 20H (kv=20, MHA) d_ff=5120
vocab=51866; enc-dec, conv frontend STUBBED (input_specs() provides
precomputed frame embeddings, encoder_seq=1500).  [arXiv:2212.04356;
unverified]

Assigned shapes apply to the decoder backbone; positional encoding uses
RoPE in this backbone reproduction (Whisper's learned absolute
embeddings are an orthogonal detail to the memory-system study).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_head=64,
    d_ff=5120, vocab_size=51866,
    n_encoder_layers=32, encoder_seq=1500, act="gelu",
)
